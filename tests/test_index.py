"""Tool-index subsystem tests: backend protocol conformance, cross-backend
consistency (exact backends identical, IVF Recall@5 floor), manager fallback
semantics, and the acceptance scenario — a live `swap_table` during IVF
serving routes correctly throughout (fallback-then-rebuild)."""
import dataclasses
import threading

import numpy as np
import pytest

from repro.data.benchmarks import scale_tool_corpus
from repro.embedding.bag_encoder import BagEncoder
from repro.index import (
    BACKENDS,
    DenseBackend,
    IVFBackend,
    IVFConfig,
    PallasBackend,
    ToolIndexManager,
    build_backend,
)
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase

SCALED_T = 3_000  # registry-scale-ish but fast to index in tests


def _db_and_encoder(bench, table=None):
    enc = BagEncoder(bench.vocab)
    base = enc.encode(bench.desc_tokens) if table is None else table
    n = base.shape[0]
    records = [
        ToolRecord(
            i,
            f"tool_{i % bench.n_tools}",
            bench.desc_tokens[i % bench.n_tools],
            int(bench.tool_category[i % bench.n_tools]),
        )
        for i in range(n)
    ]
    return ToolsDatabase(records, base), enc


@pytest.fixture(scope="module")
def scaled(small_bench):
    """(table [3000, D], queries [48, D], encoder) — shared across tests."""
    enc = BagEncoder(small_bench.vocab)
    table = scale_tool_corpus(enc.encode(small_bench.desc_tokens), SCALED_T, seed=0)
    queries = enc.encode(small_bench.query_tokens[:48])
    return table, queries, enc


# ------------------------------------------------------------------ backends
def test_registry_and_protocol(scaled):
    table, queries, _ = scaled
    assert set(BACKENDS) == {"dense", "ivf", "pallas"}
    for kind in BACKENDS:
        b = build_backend(kind, table, table_version=7)
        assert b.name == kind
        assert b.table_version == 7
        assert b.n_tools == SCALED_T
        scores, idx = b.topk(queries, 5)
        assert scores.shape == (len(queries), 5) and idx.shape == (len(queries), 5)
        assert (np.diff(scores, axis=1) <= 1e-6).all()  # sorted descending
        assert ((idx >= 0) & (idx < SCALED_T)).all()
        empty_s, empty_i = b.topk(queries[:0], 5)  # contract: any Q, even 0
        assert empty_s.shape == (0, 5) and empty_i.shape == (0, 5)
    with pytest.raises(ValueError):
        build_backend("flat", table, table_version=0)


def test_exact_backends_identical_topk(scaled):
    """dense and pallas (ref path on CPU) are both exact: identical top-K."""
    table, queries, _ = scaled
    sd, idd = DenseBackend(table, 0).topk(queries, 5)
    sp, idp = PallasBackend(table, 0).topk(queries, 5)
    assert (idd == idp).all()
    np.testing.assert_allclose(sd, sp, atol=1e-6)


def test_ivf_recall_floor_at_default_nprobe(scaled):
    """Acceptance: IVF Recall@5 >= 0.98 vs exact at the default nprobe."""
    table, queries, _ = scaled
    _, exact = DenseBackend(table, 0).topk(queries, 5)
    ivf = IVFBackend(table, 0)  # default IVFConfig
    scores, approx = ivf.topk(queries, 5)
    recall = np.mean([
        len(set(exact[j]) & set(approx[j])) / 5 for j in range(len(queries))
    ])
    assert recall >= 0.98, f"IVF recall@5 {recall:.4f} below floor"
    # the scores returned are EXACT similarities of the indexed table (the
    # shortlist is int8-approximate, the final ranking is fp32 re-ranked)
    for j in range(0, len(queries), 7):
        np.testing.assert_allclose(
            scores[j], table[approx[j]] @ queries[j], atol=1e-5
        )


def test_ivf_rejects_masks_and_tiny_tables_work(scaled):
    table, queries, _ = scaled
    ivf = IVFBackend(table, 0)
    with pytest.raises(AssertionError):
        ivf.topk(queries, 5, candidate_mask=np.ones((len(queries), SCALED_T)))
    # below the quantizer's size floor: fp32 codes path, still correct
    tiny = table[:40]
    _, exact = DenseBackend(tiny, 0).topk(queries, 5)
    _, approx = IVFBackend(tiny, 0, IVFConfig(nprobe=10)).topk(queries, 5)
    recall = np.mean([
        len(set(exact[j]) & set(approx[j])) / 5 for j in range(len(queries))
    ])
    assert recall >= 0.98


def test_ivf_warm_start_converges_faster_with_recall_parity(scaled):
    """Seeding k-means from the previous index's centroids on a gently moved
    table (the swap-triggered rebuild case) must cut iterations while
    keeping recall parity with a cold build."""
    table, queries, _ = scaled
    cold = IVFBackend(table, 0)
    # a control-plane-style swap: small refinement nudge, geometry preserved
    rng = np.random.default_rng(1)
    moved = table + 1e-3 * rng.standard_normal(table.shape).astype(np.float32)
    moved /= np.maximum(np.linalg.norm(moved, axis=-1, keepdims=True), 1e-9)
    warm = IVFBackend(moved, 1, warm_start=cold.warm_start_state())
    cold2 = IVFBackend(moved, 1)
    assert warm.kmeans_iters_run < cold2.kmeans_iters_run, (
        f"warm start did not converge faster "
        f"({warm.kmeans_iters_run} vs {cold2.kmeans_iters_run} iters)"
    )
    assert warm.kmeans_iters_run == 1  # seeded at the fixed point
    _, exact = DenseBackend(moved, 1).topk(queries, 5)

    def recall(backend):
        _, approx = backend.topk(queries, 5)
        return np.mean([
            len(set(exact[j]) & set(approx[j])) / 5 for j in range(len(queries))
        ])

    r_warm, r_cold = recall(warm), recall(cold2)
    assert r_warm >= 0.98, f"warm-start recall@5 {r_warm:.4f} below floor"
    assert r_warm >= r_cold - 0.02, (
        f"warm start lost recall vs cold build ({r_warm:.4f} vs {r_cold:.4f})"
    )
    # an incompatible warm start (wrong cluster count) is ignored, not fatal:
    # the build falls back to the cold path (identical, deterministic)
    bad = IVFBackend(moved, 2, warm_start=cold.centroids[:3])
    assert bad.kmeans_iters_run == cold2.kmeans_iters_run
    np.testing.assert_allclose(bad.centroids, cold2.centroids)


def test_manager_passes_warm_start_across_swap_rebuilds(small_bench, scaled):
    """A swap-triggered rebuild must seed from the outgoing index's
    centroids automatically (the ROADMAP 'next lever')."""
    table, queries, _ = scaled
    db, _ = _db_and_encoder(small_bench, table=table)
    manager = ToolIndexManager(db, backend="ivf", async_rebuild=False)
    assert manager.wait_ready()
    first = manager._backend
    rng = np.random.default_rng(2)
    moved = table + 1e-3 * rng.standard_normal(table.shape).astype(np.float32)
    moved /= np.maximum(np.linalg.norm(moved, axis=-1, keepdims=True), 1e-9)
    db.swap_table(moved)  # synchronous listener: rebuild completes inline
    assert manager.is_fresh()
    rebuilt = manager._backend
    assert rebuilt.table_version == db.table_version
    assert rebuilt.kmeans_iters_run < first.kmeans_iters_run, (
        "swap rebuild did not warm-start from the previous index"
    )
    scores, idx, version = manager.topk(queries, 5)
    assert version == db.table_version
    _, exact = DenseBackend(moved, version).topk(queries, 5)
    recall = np.mean([
        len(set(np.asarray(exact)[j]) & set(idx[j])) / 5 for j in range(len(queries))
    ])
    assert recall >= 0.98
    manager.close()


# ------------------------------------------------------- cross-backend router
def test_route_result_fields_consistent_across_backends(small_bench):
    """Every backend's RouteResult carries the same fields; exact backends
    agree on the ranking; scores always reproduce the final ranking."""
    expected_fields = {
        "tools", "scores", "latency_ms", "pool", "table_version",
        "stage_version", "cache_hit",
    }
    per_backend = {}
    for kind in BACKENDS:
        db, enc = _db_and_encoder(small_bench)
        router = SemanticRouter(
            db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
            index=ToolIndexManager(db, backend=kind, async_rebuild=False),
        )
        results = router.route_batch(small_bench.query_tokens[:12])
        for r in results:
            assert {f.name for f in dataclasses.fields(r)} == expected_fields
            assert r.table_version == db.table_version
            assert r.scores == sorted(r.scores, reverse=True)
            assert len(r.tools) == len(r.scores) == 5
        per_backend[kind] = results
        assert router.index.stats["served_index"] >= 1
    for a, b in zip(per_backend["dense"], per_backend["pallas"]):
        assert a.tools == b.tools  # both exact -> identical ranking
    hits = [
        len(set(a.tools) & set(b.tools))
        for a, b in zip(per_backend["dense"], per_backend["ivf"])
    ]
    assert np.mean(hits) / 5 >= 0.98


def test_masked_batches_fall_back_to_exact(small_bench):
    db, enc = _db_and_encoder(small_bench)
    manager = ToolIndexManager(db, backend="ivf", async_rebuild=False)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        index=manager,
    )
    mask = small_bench.candidate_mask()[:4]
    results = router.route_batch(small_bench.query_tokens[:4], candidate_masks=mask)
    assert manager.stats["served_exact"] >= 1
    for j, r in enumerate(results):
        allowed = set(np.flatnonzero(mask[j]).tolist())
        assert set(r.tools) <= allowed  # exact masked path honors the subset


# ------------------------------------------------------ swap-compat (manager)
def test_swap_serves_exact_fallback_then_rebuilds(small_bench, scaled):
    """Acceptance: a live swap_table during IVF serving routes correctly
    throughout — the stale index is bypassed for the exact fallback on the
    new snapshot, and the async rebuild restores index serving."""
    table, queries_emb, _ = scaled
    db, enc = _db_and_encoder(small_bench, table=table)
    # watch_swaps=False isolates the lazy path: the swap must be detected by
    # the serving call itself, not the eager listener
    manager = ToolIndexManager(
        db, backend="ivf", async_rebuild=True, watch_swaps=False,
    )
    assert manager.wait_ready(60.0)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        index=manager,
    )
    queries = small_bench.query_tokens[:8]
    r0 = router.route_batch(queries)
    assert all(r.table_version == 0 for r in r0)
    assert manager.stats["served_index"] >= 1

    perm = np.random.default_rng(0).permutation(SCALED_T)
    db.swap_table(table[perm])
    assert not manager.is_fresh()
    exact_before = manager.stats["served_exact"]
    r1 = router.route_batch(queries)  # index stale -> exact fallback + kick
    assert all(r.table_version == 1 for r in r1)
    assert manager.stats["served_exact"] == exact_before + 1
    # fallback results are EXACT similarities of the NEW table
    new_table = db.embeddings
    for r, q in zip(r1, enc.encode(queries)):
        np.testing.assert_allclose(
            r.scores, (new_table[r.tools] @ q), atol=1e-4
        )
    assert manager.wait_ready(120.0), "async rebuild never landed"
    served_idx_before = manager.stats["served_index"]
    r2 = router.route_batch(queries)
    assert all(r.table_version == 1 for r in r2)
    assert manager.stats["served_index"] == served_idx_before + 1
    assert manager.stats["rebuilds"] >= 2


def test_swap_listener_triggers_rebuild_and_reports_version(small_bench, scaled):
    """Default (watch_swaps=True): the ToolsDatabase listener rebuilds the
    index on swap AND rollback; every batch's scores stay self-consistent
    with the version it reports, even while swaps land concurrently."""
    table, _, _ = scaled
    db, enc = _db_and_encoder(small_bench, table=table)
    manager = ToolIndexManager(
        db, backend="ivf", async_rebuild=False,
        backend_opts={"config": IVFConfig(kmeans_iters=2, train_sample=1500)},
    )
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        index=manager,
    )
    tables = {0: table}
    rng = np.random.default_rng(1)
    stop = threading.Event()
    swap_err = []

    def churn():
        try:
            while not stop.is_set():
                perm = rng.permutation(SCALED_T)
                new = table[perm]
                # register BEFORE the swap: the foreground can serve the new
                # version while the sync listener rebuild is still inside
                # swap_table (only this thread swaps, so +1 is the version)
                tables[db.table_version + 1] = new
                db.swap_table(new)
        except Exception as exc:  # pragma: no cover
            swap_err.append(exc)

    thread = threading.Thread(target=churn, daemon=True)
    thread.start()
    try:
        queries = small_bench.query_tokens[:6]
        q_emb = enc.encode(queries)
        for _ in range(6):
            for r, q in zip(router.route_batch(queries), q_emb):
                served_table = tables[r.table_version]
                np.testing.assert_allclose(
                    r.scores, served_table[r.tools] @ q, atol=1e-4
                )
    finally:
        stop.set()
        thread.join()
    assert not swap_err
    # rollback also fires the listener (sync build -> immediately fresh)
    db.rollback()
    assert manager.is_fresh()


def test_close_unregisters_swap_listener(small_bench):
    """A retired manager must stop rebuilding (and pinning table copies)
    on future swaps — close() removes the database listener, idempotently."""
    db, enc = _db_and_encoder(small_bench)
    manager = ToolIndexManager(db, backend="dense", async_rebuild=False)
    rebuilds_before = manager.stats["rebuilds"]
    db.swap_table(np.roll(db.embeddings, 1, axis=0))
    assert manager.stats["rebuilds"] == rebuilds_before + 1
    manager.close()
    manager.close()  # idempotent
    db.swap_table(np.roll(db.embeddings, 2, axis=0))
    assert manager.stats["rebuilds"] == rebuilds_before + 1  # no longer watching
    assert not manager.is_fresh()
    # a closed manager still serves correctly via the lazy path
    _, _, version = manager.topk(enc.encode(small_bench.query_tokens[:2]), 5)
    assert version == db.table_version
    # router-level teardown: closes an owned manager, leaves a shared one
    owned = SemanticRouter(db, embed_fn=enc.encode_one, k=5)
    owned.close()
    assert not owned.index._watching
    shared = ToolIndexManager(db, backend="dense", async_rebuild=False)
    SemanticRouter(db, embed_fn=enc.encode_one, k=5, index=shared).close()
    assert shared._watching  # caller owns its lifecycle


def test_misconfigured_backend_opts_fail_fast(small_bench):
    """Bad backend_opts must raise at construction, not dissolve into a
    silent build-failure loop behind the exact fallback."""
    db, _ = _db_and_encoder(small_bench)
    with pytest.raises(TypeError):
        # IVFBackend takes config=IVFConfig(...), not raw kwargs
        ToolIndexManager(db, backend="ivf", backend_opts={"nprobe": 16})


def test_build_failure_keeps_fallback_serving(small_bench):
    db, enc = _db_and_encoder(small_bench)
    manager = ToolIndexManager(
        db, backend="ivf", async_rebuild=False,
        # nprobe fine, but an invalid cluster request must not kill serving
        backend_opts={"config": IVFConfig(kmeans_iters=-1)},
    )
    # construction validated good opts; force a genuinely broken build next:
    manager.backend_opts = {"config": "not-a-config"}
    db.swap_table(np.roll(db.embeddings, 1, axis=0))
    assert manager.stats["build_failures"] >= 1
    scores, idx, version = manager.topk(enc.encode(small_bench.query_tokens[:3]), 5)
    assert version == db.table_version
    assert idx.shape == (3, 5)
    assert manager.stats["served_exact"] >= 1
