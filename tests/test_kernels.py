"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes and dtypes
(interpret=True executes the Pallas body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.topk_sim.kernel import topk_sim_pallas
from repro.kernels.topk_sim.ref import topk_sim_ref

RNG = np.random.default_rng(0)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


# ------------------------------------------------------------------ topk_sim
@pytest.mark.parametrize(
    "q,t,d,k",
    [(7, 199, 384, 5), (1, 50, 384, 10), (128, 2413, 384, 25), (33, 513, 256, 3)],
)
def test_topk_sim_shapes(q, t, d, k):
    qe = _unit(RNG.normal(size=(q, d))).astype(np.float32)
    te = _unit(RNG.normal(size=(t, d))).astype(np.float32)
    rv, ri = topk_sim_ref(jnp.asarray(qe), jnp.asarray(te), k)
    pv, pi = topk_sim_pallas(jnp.asarray(qe), jnp.asarray(te), k, interpret=True)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(pv), atol=1e-5)
    assert (np.asarray(ri) == np.asarray(pi)).all()


def test_topk_sim_tie_handling():
    """Rows with BITWISE-tied scores spanning the BLOCK_T tile boundary:
    kernel and ref must both resolve ties to the LOWEST index (the kernel's
    stable merge sort keeps earlier-tile candidates ahead of later ones,
    matching lax.top_k's tie order) — pinned before the Pallas path serves
    traffic. One-hot table rows make every duplicate's dot product a single
    float term, so ties are exact regardless of GEMM summation order
    (duplicated *dense* rows can differ in the last ulp across column
    blocks and would not actually tie)."""
    d = 128
    base = np.zeros((9, d), np.float32)
    base[np.arange(9), np.arange(9)] = 1.0  # unit one-hot rows
    te = np.tile(base, (70, 1))  # 630 rows: exact ties across 2 tiles
    qe = _unit(RNG.normal(size=(4, d))).astype(np.float32)
    rv, ri = topk_sim_ref(jnp.asarray(qe), jnp.asarray(te), 8)
    pv, pi = topk_sim_pallas(jnp.asarray(qe), jnp.asarray(te), 8, interpret=True)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(pv), atol=1e-6)
    assert (np.asarray(ri) == np.asarray(pi)).all()
    # all 70 copies of each query's best one-hot row tie at the max score,
    # so lowest-index-first tie order means the top-8 must be exactly the 8
    # lowest-indexed copies of that row: best, best+9, ..., best+63
    best = np.argmax(qe[:, :9], axis=1)  # score of one-hot row r is qe[:, r]
    expected = best[:, None] + 9 * np.arange(8)[None, :]
    np.testing.assert_array_equal(np.asarray(pi), expected)


@pytest.mark.parametrize("t,k", [(513, 10), (37, 20), (512, 5)])
def test_topk_sim_padded_tail_masking(t, k):
    """T is padded up to a BLOCK_T multiple inside the kernel; the padded
    tail must never surface as an index or a score. t=513 leaves a 511-row
    padded tail in tile 2; t=37 leaves a 475-row tail in a single tile."""
    qe = _unit(RNG.normal(size=(6, 384))).astype(np.float32)
    te = _unit(RNG.normal(size=(t, 384))).astype(np.float32)
    rv, ri = topk_sim_ref(jnp.asarray(qe), jnp.asarray(te), k)
    pv, pi = topk_sim_pallas(jnp.asarray(qe), jnp.asarray(te), k, interpret=True)
    pi, pv = np.asarray(pi), np.asarray(pv)
    assert ((pi >= 0) & (pi < t)).all()  # no padded-row index leaks
    assert (pv > -1e29).all()  # no NEG sentinel leaks (k <= t real rows)
    np.testing.assert_allclose(np.asarray(rv), pv, atol=1e-5)
    assert (np.asarray(ri) == pi).all()


@given(st.integers(1, 40), st.integers(30, 200), st.integers(1, 8), st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_topk_sim_property(q, t, k, seed):
    rng = np.random.default_rng(seed)
    qe = _unit(rng.normal(size=(q, 64))).astype(np.float32)
    te = _unit(rng.normal(size=(t, 64))).astype(np.float32)
    rv, _ = topk_sim_ref(jnp.asarray(qe), jnp.asarray(te), k)
    pv, pi = topk_sim_pallas(jnp.asarray(qe), jnp.asarray(te), k, interpret=True)
    # scores agree and are sorted descending; indices in range
    np.testing.assert_allclose(np.asarray(rv), np.asarray(pv), atol=1e-5)
    pv = np.asarray(pv)
    assert (np.diff(pv, axis=1) <= 1e-6).all()
    assert ((np.asarray(pi) >= 0) & (np.asarray(pi) < t)).all()


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize(
    "bh,sq,skv,hd,causal,window,q_offset",
    [
        (2, 128, 128, 64, True, 0, 0),
        (3, 200, 200, 64, True, 0, 0),
        (2, 256, 256, 128, True, 64, 0),
        (1, 1, 300, 64, True, 0, 299),  # decode step
        (2, 128, 128, 80, False, 0, 0),  # cross-attention, padded head dim
        (1, 96, 160, 64, True, 0, 64),  # chunked prefill continuation
    ],
)
def test_flash_attention_shapes(bh, sq, skv, hd, causal, window, q_offset):
    q = RNG.normal(size=(bh, sq, hd)).astype(np.float32)
    k = RNG.normal(size=(bh, skv, hd)).astype(np.float32)
    v = RNG.normal(size=(bh, skv, hd)).astype(np.float32)
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal, window, q_offset)
    got = flash_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, q_offset=q_offset, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-5)


def test_flash_attention_bf16():
    q = RNG.normal(size=(2, 128, 64)).astype(np.float32)
    k = RNG.normal(size=(2, 128, 64)).astype(np.float32)
    v = RNG.normal(size=(2, 128, 64)).astype(np.float32)
    ref = attention_ref(*(jnp.asarray(x, jnp.bfloat16) for x in (q, k, v)))
    got = flash_attention_pallas(
        *(jnp.asarray(x, jnp.bfloat16) for x in (q, k, v)), interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(got, np.float32), atol=3e-2
    )


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "b,s,h,p,g,n,chunk",
    [(2, 256, 4, 64, 1, 128, 64), (1, 512, 8, 64, 2, 64, 128), (2, 128, 2, 32, 1, 16, 32)],
)
def test_ssd_scan_shapes(b, s, h, p, g, n, chunk):
    x = RNG.normal(size=(b, s, h, p)).astype(np.float32)
    dt = (0.1 + 0.5 * RNG.random((b, s, h))).astype(np.float32)
    a_log = (RNG.normal(size=(h,)) * 0.5).astype(np.float32)
    bm = (RNG.normal(size=(b, s, g, n)) * 0.3).astype(np.float32)
    cm = (RNG.normal(size=(b, s, g, n)) * 0.3).astype(np.float32)
    ry, rst = ssd_scan_ref(*map(jnp.asarray, (x, dt, a_log, bm, cm)), chunk)
    py, pst = ssd_scan_pallas(*map(jnp.asarray, (x, dt, a_log, bm, cm)), chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(ry), np.asarray(py), atol=1e-3)
    np.testing.assert_allclose(np.asarray(rst), np.asarray(pst), atol=1e-3)


def test_ssd_scan_matches_sequential_recurrence():
    """Chunked SSD == naive per-token recurrence (the SSM decode path)."""
    b, s, h, p, n, chunk = 1, 64, 2, 16, 8, 16
    x = RNG.normal(size=(b, s, h, p)).astype(np.float32)
    dt = (0.1 + 0.3 * RNG.random((b, s, h))).astype(np.float32)
    a_log = (RNG.normal(size=(h,)) * 0.3).astype(np.float32)
    bm = (RNG.normal(size=(b, s, 1, n)) * 0.3).astype(np.float32)
    cm = (RNG.normal(size=(b, s, 1, n)) * 0.3).astype(np.float32)
    y_k, st_k = ssd_scan_pallas(*map(jnp.asarray, (x, dt, a_log, bm, cm)), chunk, interpret=True)
    # naive recurrence
    a = -np.exp(a_log)
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        da = np.exp(dt[:, t] * a)  # [b,h]
        bx = np.einsum("bh,bhn,bhp->bhpn", dt[:, t], bm[:, t, 0][:, None, :].repeat(h, 1), x[:, t])
        state = state * da[:, :, None, None] + bx
        ys[:, t] = np.einsum("bhn,bhpn->bhp", cm[:, t, 0][:, None, :].repeat(h, 1), state)
    np.testing.assert_allclose(np.asarray(y_k), ys, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_k), state, atol=1e-3)
