"""Learning-plane tests: ArtifactRegistry versioning/persistence, StageSet
CAS + rollback on the router, gated promotion + suppression semantics of the
LearningController, StageGuard auto-demotion, and a threaded smoke test of
route_batch concurrent with stage churn (scores must stay self-consistent
with the reported (table_version, stage_version))."""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.control import OutcomeStore
from repro.core import adapter as adapter_lib
from repro.core.deployment import DeploymentPlan
from repro.embedding.bag_encoder import BagEncoder
from repro.learn import (
    ArtifactRegistry,
    LearnConfig,
    LearningController,
    StageGuard,
    StageGuardConfig,
    TrainedStage,
    build_train_window,
    featurizer_from_tree,
    featurizer_to_tree,
)
from repro.router.gateway import SemanticRouter, StageSet
from repro.router.tooldb import ConflictError, ToolRecord, ToolsDatabase


def _db_and_encoder(bench, **kw):
    enc = BagEncoder(bench.vocab)
    records = [
        ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
        for i in range(bench.n_tools)
    ]
    return ToolsDatabase(records, enc.encode(bench.desc_tokens), **kw), enc


def _serve(router, bench, idx, observe=None, batch_size=64):
    for lo in range(0, len(idx), batch_size):
        chunk = idx[lo : lo + batch_size]
        results = router.route_batch([bench.query_tokens[qi] for qi in chunk])
        for qi, res in zip(chunk, results):
            for t in res.tools:
                router.record_outcome(
                    bench.query_tokens[qi], t, int(t in bench.relevant[qi])
                )
            if observe is not None:
                observe(res, bench.relevant[qi])


def _forced_plan(refine=True, rerank=False, adapter=False):
    def plan_fn(n_tools, n_examples):
        return DeploymentPlan(
            refine=refine, mlp_reranker=rerank, contrastive_adapter=adapter,
            density=n_examples / max(n_tools, 1), reason="forced (test)",
        )

    return plan_fn


def _learn_world(bench, *, plan_fn, min_new_events=50, guard=None, **cfg_kw):
    db, enc = _db_and_encoder(bench)
    store = OutcomeStore(n_tools=len(db), capacity=50_000)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    learner = LearningController(
        db, store, router, enc.encode,
        guard=guard,
        config=LearnConfig(min_new_events=min_new_events, min_queries=10, **cfg_kw),
        plan_fn=plan_fn,
    )
    return db, enc, store, router, learner


# ------------------------------------------------------------ ArtifactRegistry


def test_registry_versions_bounded_latest_and_discard():
    reg = ArtifactRegistry(history_limit=3)
    for i in range(5):
        art = reg.register(
            "adapter", {"w": np.full((2, 2), i, np.float32)},
            table_version=i, fingerprint=f"fp{i}",
        )
        assert art.version == i + 1
    assert reg.versions("adapter") == [3, 4, 5]  # bounded: oldest evicted
    assert reg.latest("adapter").version == 5
    with pytest.raises(KeyError):
        reg.get("adapter", 1)
    reg.discard("adapter", 5)
    assert reg.latest("adapter").version == 4
    reg.discard("adapter", 99)  # idempotent on unknown versions


def test_registry_rollback_drops_newer_versions():
    reg = ArtifactRegistry()
    for i in range(3):
        reg.register("rerank", {"w": np.zeros(1)}, table_version=0, fingerprint="f")
    art = reg.rollback("rerank")
    assert art.version == 2 and reg.versions("rerank") == [1, 2]
    art = reg.rollback("rerank", to_version=1)
    assert art.version == 1 and reg.versions("rerank") == [1]
    with pytest.raises(RuntimeError):
        reg.rollback("rerank")  # nothing older retained


def test_registry_persistence_roundtrip(tmp_path, small_bench):
    from repro.core.features import OutcomeFeaturizer

    enc = BagEncoder(small_bench.vocab)
    tr = small_bench.train_idx[:40]
    qe = enc.encode([small_bench.query_tokens[i] for i in tr])
    rel = small_bench.relevance_matrix()[tr]
    table = enc.encode(small_bench.desc_tokens)
    retrieved = np.argsort(-(qe @ table.T), axis=1)[:, :5]
    feat = OutcomeFeaturizer.fit(
        qe, [small_bench.query_tokens[i] for i in tr], rel, retrieved,
        small_bench.tool_category,
    )
    reg = ArtifactRegistry()
    params = adapter_lib.init_adapter(jax.random.PRNGKey(0))
    reg.register(
        "adapter", {k: np.asarray(v) for k, v in params.items()},
        table_version=3, fingerprint="abcd", metrics={"ndcg_candidate": 0.9},
    )
    reg.register(
        "rerank", {"w0": np.ones((7, 4), np.float32)},
        table_version=3, fingerprint="abcd", aux=featurizer_to_tree(feat),
    )
    reg.save(str(tmp_path))
    back = ArtifactRegistry.restore(str(tmp_path))
    art = back.latest("adapter")
    assert art.table_version == 3 and art.fingerprint == "abcd"
    assert art.metrics["ndcg_candidate"] == pytest.approx(0.9)
    np.testing.assert_allclose(art.params["w1"], np.asarray(params["w1"]))
    feat_back = featurizer_from_tree(back.latest("rerank").aux)
    np.testing.assert_allclose(feat_back.success_rate, feat.success_rate)
    assert feat_back.mean_query_len == pytest.approx(feat.mean_query_len)
    # registered versions keep counting from where the saved registry stopped
    assert back.register(
        "adapter", {"w": np.zeros(1)}, table_version=4, fingerprint="x"
    ).version == 2


# ------------------------------------------------- StageSet CAS on the router


def test_stage_cas_and_bounded_history(small_bench):
    db, enc = _db_and_encoder(small_bench)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        stage_history_limit=2,
    )
    params = adapter_lib.init_adapter(jax.random.PRNGKey(0))
    v1 = router.set_stages(StageSet(adapter_params=params), expect_version=0)
    assert v1 == 1 and router.stage_set()[1].has_adapter
    with pytest.raises(ConflictError):
        router.set_stages(StageSet(), expect_version=0)  # stale expectation
    v2 = router.set_stages(StageSet(), expect_version=v1)
    v3 = router.set_stages(StageSet(adapter_params=params))
    assert router.retained_stage_versions() == [v1, v2]  # bounded at 2
    # rollback refuses when the judged version is no longer live
    with pytest.raises(ConflictError):
        router.rollback_stages(expect_current=v2)
    v4 = router.rollback_stages(expect_current=v3)
    assert v4 == 4 and not router.stage_set()[1].has_adapter
    # the condemned v3 was not retained; v1 remains a target
    assert router.retained_stage_versions() == [v1]


def test_route_scores_match_reported_stage_version(small_bench):
    """RouteResult.scores must be the exact similarities of the adapted
    query against the reported table_version — recomputable from the
    reported (table_version, stage_version) pair."""
    db, enc = _db_and_encoder(small_bench)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5
    )
    rng = np.random.default_rng(0)
    params = adapter_lib.init_adapter(jax.random.PRNGKey(1))
    params = {  # non-identity: adapted scores must differ from raw ones
        k: (v if k != "w2" else 0.3 * rng.standard_normal(v.shape).astype(np.float32))
        for k, v in params.items()
    }
    router.set_stages(StageSet(adapter_params=params))
    q_tokens = small_bench.query_tokens[small_bench.test_idx[0]]
    res = router.route(q_tokens)
    assert res.stage_version == 1
    qe = enc.encode_one(q_tokens)[None]
    q_adapted = StageSet(adapter_params=params).adapt_queries(qe)[0]
    sims = db.embeddings @ q_adapted
    expect = np.sort(sims)[::-1][:5]
    np.testing.assert_allclose(res.scores, expect, atol=1e-5)
    raw_top = np.sort(db.embeddings @ qe[0])[::-1][:5]
    assert not np.allclose(expect, raw_top, atol=1e-5)


def test_adapter_stage_composes_with_backends(small_bench):
    """The adapter transforms queries BEFORE the index backend scores, so
    dense and pallas (exact paths) must agree on the adapted ranking."""
    db, enc = _db_and_encoder(small_bench)
    params = adapter_lib.init_adapter(jax.random.PRNGKey(2))
    rng = np.random.default_rng(1)
    params["w2"] = 0.3 * rng.standard_normal(params["w2"].shape).astype(np.float32)
    stages = StageSet(adapter_params=params)
    queries = [small_bench.query_tokens[i] for i in small_bench.test_idx[:8]]
    results = {}
    for backend in ("dense", "pallas"):
        router = SemanticRouter(
            db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
            backend=backend, stages=stages,
        )
        assert router.index.wait_ready()
        results[backend] = router.route_batch(queries)
        router.close()
    for rd, rp in zip(results["dense"], results["pallas"]):
        assert rd.tools == rp.tools
        np.testing.assert_allclose(rd.scores, rp.scores, atol=1e-5)


# ----------------------------------------------------------- LearningController


class _CountingTrainer:
    """Stub trainer: returns an identity adapter, counts invocations."""

    stage = "adapter"

    def __init__(self):
        self.calls = 0

    def train(self, window, live_stages=None):
        self.calls += 1
        params = adapter_lib.init_adapter(jax.random.PRNGKey(0))
        return TrainedStage(
            stage="adapter",
            params={k: np.asarray(v) for k, v in params.items()},
            aux={},
            info={},
        )


def test_plan_suppression_never_trains(small_bench):
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(rerank=False, adapter=False)
    )
    counting = _CountingTrainer()
    learner.trainers["adapter"] = counting
    _serve(router, small_bench, small_bench.train_idx[:40])
    report = learner.step()
    assert report.decisions["adapter"].action == "suppressed"
    assert report.decisions["rerank"].action == "suppressed"
    assert counting.calls == 0, "a plan-vetoed stage must never even train"
    assert report.active == frozenset()


def test_below_trigger_skips_training(small_bench):
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(adapter=True), min_new_events=10_000
    )
    counting = _CountingTrainer()
    learner.trainers["adapter"] = counting
    _serve(router, small_bench, small_bench.train_idx[:20])
    report = learner.step()
    assert report.decisions["adapter"].action == "below_trigger"
    assert counting.calls == 0


def test_gate_rejects_non_improvement(small_bench):
    """An identity adapter ties the live config's NDCG; min_gain=0 promotion
    requires strict improvement, so the tie must be rejected."""
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(adapter=True)
    )
    learner.trainers["adapter"] = _CountingTrainer()
    _serve(router, small_bench, small_bench.train_idx[:60])
    report = learner.step()
    d = report.decisions["adapter"]
    assert d.action == "gate_rejected"
    assert d.ndcg_candidate == pytest.approx(d.ndcg_current, abs=1e-6)
    assert learner.registry.latest("adapter") is None
    assert router.stage_version == 0
    # the trigger watermark was consumed: the next step does not retrain
    # until fresh evidence arrives
    assert learner.step().decisions["adapter"].action == "below_trigger"


def test_real_adapter_promotion_lifts_heldout_ndcg(small_bench):
    """Real training end-to-end on a forced-dense plan: the adapter must
    clear the held-out gate, activate via CAS, and register its artifact
    stamped with (table_version, window fingerprint)."""
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(adapter=True)
    )
    _serve(router, small_bench, small_bench.train_idx)
    fp = store.window_fingerprint()
    report = learner.step()
    d = report.decisions["adapter"]
    assert d.action == "promoted", d
    assert d.ndcg_candidate > d.ndcg_current
    assert report.active == frozenset({"adapter"})
    art = learner.registry.latest("adapter")
    assert art is not None and art.version == d.artifact_version
    assert art.table_version == db.table_version
    assert art.fingerprint == fp
    _, stages = router.stage_set()
    assert stages.adapter_artifact == art.version


def test_sparse_window_rerank_is_gate_rejected(small_bench):
    """Even if the density plan is bypassed (forced), the held-out gate must
    stop the re-ranker trained on a sparse window — the paper's §7.3
    negative result enforced by measurement."""
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(rerank=True)
    )
    _serve(router, small_bench, small_bench.train_idx[:120])
    report = learner.step()
    d = report.decisions["rerank"]
    assert d.action in ("gate_rejected", "train_failed"), d
    assert not router.stage_set()[1].has_reranker


def test_table_swap_mid_training_stands_down(small_bench):
    """A refinement swap landing mid-training stales the gate's evidence:
    the promotion must stand down instead of activating on a table the
    gate never saw."""
    db, enc, store, router, learner = _learn_world(
        small_bench, plan_fn=_forced_plan(adapter=True), min_gain=-1.0
    )

    class SwappingTrainer(_CountingTrainer):
        def train(self, window, live_stages=None):
            db.swap_table(db.embeddings.copy())  # concurrent refinement
            return super().train(window, live_stages)

    learner.trainers["adapter"] = SwappingTrainer()
    _serve(router, small_bench, small_bench.train_idx[:60])
    report = learner.step()
    d = report.decisions["adapter"]
    assert d.action == "table_moved", d
    assert learner.registry.latest("adapter") is None
    assert router.stage_version == 0


def test_activation_conflict_discards_artifact(small_bench):
    class RacingRouter(SemanticRouter):
        def set_stages(self, stages, expect_version=None):
            raise ConflictError("lost the race (test)")

    db, enc = _db_and_encoder(small_bench)
    store = OutcomeStore(n_tools=len(db), capacity=50_000)
    router = RacingRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    learner = LearningController(
        db, store, router, enc.encode,
        config=LearnConfig(min_new_events=50, min_queries=10, min_gain=-1.0),
        plan_fn=_forced_plan(adapter=True),
    )
    learner.trainers["adapter"] = _CountingTrainer()
    _serve(router, small_bench, small_bench.train_idx[:60])
    report = learner.step()
    d = report.decisions["adapter"]
    assert d.action == "activation_conflict"
    # the never-deployed artifact must not linger as latest
    assert learner.registry.latest("adapter") is None


# -------------------------------------------------------------- StageGuard


def test_stage_guard_demotes_regressing_promotion(small_bench):
    guard_cfg = StageGuardConfig(min_samples=16, tolerance=0.02)
    db, enc, store, router, learner = _learn_world(
        small_bench,
        plan_fn=_forced_plan(adapter=True),
        min_gain=-1.0,  # promote the identity stub so we control quality
    )
    guard = StageGuard(router, guard_cfg)
    learner.guard = guard
    learner.trainers["adapter"] = _CountingTrainer()
    observe = lambda res, rel: guard.observe(res.stage_version, res.tools, rel)
    # build a rolling window on stage v0 so the promotion gets a baseline
    _serve(router, small_bench, small_bench.train_idx[:40], observe)
    report = learner.step()
    assert report.decisions["adapter"].action == "promoted"
    promoted_v = report.stage_version
    assert guard.check().action in ("insufficient_data", "no_baseline", "healthy")
    # live labels regress hard on the promoted version (simulated bad stage)
    for _ in range(guard_cfg.min_samples):
        guard.observe(promoted_v, [0, 1, 2, 3, 4], [59])  # never relevant
    report = learner.step()
    assert report.guard.action == "demoted"
    assert report.guard.restored_version == router.stage_version
    assert not router.stage_set()[1].has_adapter  # back to the v0 stage set
    assert report.reason.startswith("cooldown after stage demotion")
    # the condemned-era window was purged: a retrain from it would pass the
    # same gate the condemned artifact passed and flap
    assert len(store) == 0
    # the registry followed the demotion: the condemned artifact cannot
    # linger as `latest` (the restored set serves no adapter artifact)
    assert learner.registry.latest("adapter") is None
    # cooldown consumed the watermark: no immediate retrain attempt
    report = learner.step()
    assert report.decisions["adapter"].action == "below_trigger"


def test_stage_guard_handles_out_of_band_promotion(small_bench):
    """An unannounced set_stages (bypassing the controller) must still get a
    baseline frozen from its predecessor and be demotable."""
    db, enc = _db_and_encoder(small_bench)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5
    )
    guard = StageGuard(router, StageGuardConfig(min_samples=8, tolerance=0.02))
    for _ in range(8):
        guard.observe(0, [0, 1, 2, 3, 4], [0])  # perfect NDCG on v0
    params = adapter_lib.init_adapter(jax.random.PRNGKey(0))
    router.set_stages(StageSet(adapter_params=params))  # no note_promotion
    for _ in range(8):
        guard.observe(1, [0, 1, 2, 3, 4], [59])  # regressing labels on v1
    report = guard.check()
    assert report.action == "demoted" and report.baseline == pytest.approx(1.0)
    assert guard.demotions and router.stage_version == 2


# ------------------------------------------------------------ window plumbing


def test_window_fingerprint_tracks_window_content():
    store = OutcomeStore(n_tools=4, capacity=100)
    from repro.router.gateway import OutcomeEvent

    fp0 = store.window_fingerprint()
    store.append(OutcomeEvent(np.array([1, 2]), 1, 1, 0.0))
    fp1 = store.window_fingerprint()
    assert fp1 != fp0
    assert store.window_fingerprint() == fp1  # stable when nothing changes
    store.clear()
    assert store.window_fingerprint() not in (fp0, fp1)  # watermark moved on


def test_build_train_window_splits_on_positive_rows(small_bench):
    db, enc = _db_and_encoder(small_bench)
    store = OutcomeStore(n_tools=len(db), capacity=50_000)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        outcome_sink=store.append,
    )
    assert build_train_window(db, store, enc.encode) is None  # empty window
    _serve(router, small_bench, small_bench.train_idx[:80])
    window = build_train_window(db, store, enc.encode, min_queries=10)
    assert window is not None
    assert len(np.intersect1d(window.train_idx, window.val_idx)) == 0
    # every held-out gate row carries at least one logged success
    assert (window.pos_mask[window.val_idx].sum(axis=1) > 0).all()
    assert window.table_version == db.table_version
    assert window.fingerprint == store.window_fingerprint()


# ------------------------------------------------------- threaded stage churn


@pytest.mark.slow
def test_route_batch_concurrent_with_stage_churn(small_bench):
    """Scores must stay self-consistent with the reported
    (table_version, stage_version) while a churn thread promotes/demotes
    stage sets under live batched serving."""
    db, enc = _db_and_encoder(small_bench)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        stage_history_limit=4,
    )
    rng = np.random.default_rng(0)
    params = adapter_lib.init_adapter(jax.random.PRNGKey(3))
    params["w2"] = 0.3 * rng.standard_normal(params["w2"].shape).astype(np.float32)
    adapter_sets = {True: StageSet(adapter_params=params), False: StageSet()}
    stop = threading.Event()
    n_churn = [0]

    def churn():
        # only this thread promotes, so versions are assigned sequentially
        # and version v carries the adapter iff v is odd (v0 = no adapter)
        while not stop.is_set():
            router.set_stages(adapter_sets[n_churn[0] % 2 == 0])
            n_churn[0] += 1

    queries = [small_bench.query_tokens[i] for i in small_bench.test_idx[:16]]
    q_emb = enc.encode(queries)
    q_adapted = adapter_sets[True].adapt_queries(q_emb)
    table = db.embeddings  # no table churn in this test: isolate the stages
    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        for _ in range(30):
            results = router.route_batch(queries)
            for j, res in enumerate(results):
                assert res.table_version == 0
                q = q_adapted[j] if res.stage_version % 2 == 1 else q_emb[j]
                expect = np.sort(table @ q)[::-1][: len(res.scores)]
                np.testing.assert_allclose(res.scores, expect, atol=1e-4)
    finally:
        stop.set()
        t.join()
    assert n_churn[0] > 0
