"""Regression tests for the two serving-substrate contracts this repo pins:

* `repro.common.meshctx` — the JAX-version-portable mesh context: no mesh
  means logical constraints are no-ops, an explicit `use_mesh` resolves
  logical axes to sharded specs, and the registry fallback works even when
  no native JAX mesh setter exists.
* `SemanticRouter.route_batch` — batching is semantics-preserving: a batch
  of Q queries returns exactly what Q sequential `route()` calls return,
  with and without candidate masks, with and without the Stage-2 re-ranker,
  and `RouteResult.scores` always matches the ranking actually applied.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import meshctx
from repro.common.sharding import logical_constraint, named_sharding, spec_for
from repro.core import reranker as reranker_lib
from repro.core.features import OutcomeFeaturizer
from repro.embedding.bag_encoder import BagEncoder
from repro.router.gateway import SemanticRouter
from repro.router.scheduler import ContinuousBatcher, Request
from repro.router.tooldb import ToolRecord, ToolsDatabase


# ------------------------------------------------------------------ meshctx
def test_no_mesh_constraint_is_noop():
    assert meshctx.current_mesh() is None
    x = jnp.arange(12.0).reshape(3, 4)
    y = logical_constraint(x, "batch", "embed")
    assert y is x  # literally untouched, not just equal


def test_use_mesh_resolves_logical_axes_to_sharded_spec():
    mesh = meshctx.make_mesh((len(jax.devices()),), ("data",))
    with meshctx.use_mesh(mesh):
        got = meshctx.current_mesh()
        assert got is not None and "data" in got.axis_names
        # "batch" -> ("pod","data") intersected with this mesh -> ("data",)
        ns = named_sharding(mesh, ("batch", None), shape=(4, 8))
        assert ns.spec == jax.sharding.PartitionSpec("data", None)
        # constraint applies inside jit without error and preserves values
        x = jnp.arange(8.0).reshape(2, 4)
        y = jax.jit(lambda a: logical_constraint(a, "batch", None))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x))
    assert meshctx.current_mesh() is None


def test_use_mesh_registry_fallback_and_nesting():
    mesh = meshctx.make_mesh((1,), ("data",))
    inner = meshctx.make_mesh((1,), ("model",))
    with meshctx.use_mesh(mesh):
        assert meshctx.current_mesh().axis_names == ("data",)
        with meshctx.use_mesh(inner):
            assert meshctx.current_mesh().axis_names == ("model",)
        assert meshctx.current_mesh().axis_names == ("data",)
    assert meshctx.current_mesh() is None


def test_axis_sizes_dict_concrete_and_sizes():
    mesh = meshctx.make_mesh((1, 1), ("data", "model"))
    assert meshctx.axis_sizes_dict(mesh) == {"data": 1, "model": 1}
    assert spec_for(("batch", None), mesh.axis_names) == jax.sharding.PartitionSpec(
        "data", None
    )


# --------------------------------------------------------------- route_batch
@pytest.fixture(scope="module")
def router_parts(request):
    bench = request.getfixturevalue("small_bench")
    enc = BagEncoder(bench.vocab)
    records = [
        ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
        for i in range(bench.n_tools)
    ]
    db = ToolsDatabase(records, enc.encode(bench.desc_tokens))
    return bench, enc, db


def _assert_batch_matches_sequential(router, queries, masks=None):
    batch = router.route_batch(queries, masks)
    for j, q in enumerate(queries):
        single = router.route(q, None if masks is None else masks[j])
        assert batch[j].tools == single.tools, j
        np.testing.assert_allclose(batch[j].scores, single.scores, rtol=0, atol=1e-5)
        assert batch[j].table_version == single.table_version


def test_route_batch_matches_sequential(router_parts):
    bench, enc, db = router_parts
    router = SemanticRouter(db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5)
    queries = [bench.query_tokens[i] for i in bench.test_idx[:16]]
    _assert_batch_matches_sequential(router, queries)


def test_route_batch_matches_sequential_with_masks(router_parts):
    bench, enc, db = router_parts
    router = SemanticRouter(db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=3)
    rng = np.random.default_rng(0)
    queries = [bench.query_tokens[i] for i in bench.test_idx[:12]]
    masks = (rng.random((len(queries), bench.n_tools)) < 0.5).astype(np.float32)
    masks[:, :3] = 1.0  # every query keeps at least k candidates
    _assert_batch_matches_sequential(router, queries, masks)
    # masked-out tools never selected
    for j, res in enumerate(router.route_batch(queries, masks)):
        assert all(masks[j, t] > 0 for t in res.tools)


def test_route_batch_mask_with_fewer_than_k_candidates(router_parts):
    """A mask admitting < k tools must yield a short result, never the
    masked-out ids that pad the top-k slots."""
    bench, enc, db = router_parts
    router = SemanticRouter(db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5)
    queries = [bench.query_tokens[i] for i in bench.test_idx[:4]]
    masks = np.zeros((len(queries), bench.n_tools), np.float32)
    allowed = [[7], [2, 11], [0, 1, 3], [5, 6]]
    for j, ids in enumerate(allowed):
        masks[j, ids] = 1.0
    _assert_batch_matches_sequential(router, queries, masks)
    for j, res in enumerate(router.route_batch(queries, masks)):
        assert set(res.tools) <= set(allowed[j])
        assert len(res.tools) == len(allowed[j]) == len(res.scores)
        assert all(s > -1e29 for s in res.scores)


def _fit_featurizer_and_mlp(bench, enc, db, k=5):
    rel = bench.relevance_matrix()
    tr = bench.train_idx
    qe = enc.encode([bench.query_tokens[i] for i in tr])
    sims = qe @ db.embeddings.T
    retrieved = np.argsort(-sims, axis=1)[:, :k]
    feat = OutcomeFeaturizer.fit(
        qe,
        [bench.query_tokens[i] for i in tr],
        rel[tr],
        retrieved,
        bench.tool_category,
    )
    params = reranker_lib.init_mlp(jax.random.PRNGKey(0))
    return feat, params


def test_route_batch_matches_sequential_with_rerank(router_parts):
    bench, enc, db = router_parts
    feat, mlp = _fit_featurizer_and_mlp(bench, enc, db)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        mlp_params=mlp, featurizer=feat,
    )
    queries = [bench.query_tokens[i] for i in bench.test_idx[:12]]
    _assert_batch_matches_sequential(router, queries)


def test_rerank_scores_are_the_ranking_scores(router_parts):
    """RouteResult.scores must be the f_phi scores that ordered the top-K,
    not the pre-rerank similarities (the seed bug this PR fixes)."""
    bench, enc, db = router_parts
    feat, mlp = _fit_featurizer_and_mlp(bench, enc, db)
    router = SemanticRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
        mlp_params=mlp, featurizer=feat,
    )
    q = bench.query_tokens[bench.test_idx[0]]
    res = router.route(q)
    assert res.scores == sorted(res.scores, reverse=True)
    # recompute the expected MLP ranking independently
    qe = enc.encode_one(q)
    sims = db.embeddings @ qe
    c = min(router.k * router.candidate_multiplier, len(db))
    order = np.argsort(-sims)[:c]
    feats = feat.features(qe[None], [q], order[None], sims[order][None])
    mlp_scores = np.asarray(reranker_lib.mlp_forward(mlp, jnp.asarray(feats)))[0]
    rank = np.argsort(-mlp_scores, kind="stable")[: router.k]
    assert res.tools == [int(order[r]) for r in rank]
    np.testing.assert_allclose(res.scores, mlp_scores[rank], rtol=0, atol=1e-5)


def test_route_with_table_smaller_than_k(router_parts):
    """k larger than the tool table must yield a short result on both the
    dense and the re-rank path (the latter used to crash in top_k)."""
    bench, enc, db = router_parts
    feat, mlp = _fit_featurizer_and_mlp(bench, enc, db)
    small_db = ToolsDatabase(
        [db.record(i) for i in range(3)], db.embeddings[:3].copy()
    )
    q = bench.query_tokens[bench.test_idx[0]]
    for kwargs in ({}, {"mlp_params": mlp, "featurizer": feat}):
        router = SemanticRouter(
            small_db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5,
            **kwargs,
        )
        res = router.route(q)
        assert len(res.tools) == len(res.scores) == 3
        assert set(res.tools) == {0, 1, 2}


def test_scheduler_admission_routes_in_batch(router_parts):
    """The admission loop attaches tools via ONE route_batch call per tick."""
    bench, enc, db = router_parts
    calls = []

    class CountingRouter(SemanticRouter):
        def route_batch(self, queries, candidate_masks=None):
            calls.append(len(queries))
            return super().route_batch(queries, candidate_masks)

    router = CountingRouter(
        db, embed_fn=enc.encode_one, embed_batch_fn=enc.encode, k=5
    )
    # exercise only the admission-side routing (no backend model needed)
    sched = ContinuousBatcher.__new__(ContinuousBatcher)
    sched.router = router
    sched.slots = [None] * 4
    sched.queue = collections.deque(
        Request(request_id=i, prompt=np.zeros(4, np.int32), max_new_tokens=1,
                query_tokens=bench.query_tokens[i])
        for i in range(6)
    )
    sched._route_admissible()
    assert calls == [4]  # one batched call covering the 4 free slots
    routed = [r for r in sched.queue if r.tools is not None]
    assert len(routed) == 4
    expected = router.route_batch([r.query_tokens for r in routed])
    for req, exp in zip(routed, expected):
        assert req.tools == exp.tools
        assert req.route_result.table_version == exp.table_version
