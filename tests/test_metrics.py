"""Retrieval metrics: unit cases + hypothesis properties + jnp/np agreement."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.metrics.retrieval import (
    batched_ndcg_at_k,
    batched_recall_at_k,
    evaluate_ranking,
    mrr,
    ndcg_at_k,
    precision_at_k,
    recall_at_k,
)


def test_known_values():
    ranked = [3, 1, 2, 0, 4]
    rel = {1, 4}
    assert recall_at_k(ranked, rel, 1) == 0.0
    assert recall_at_k(ranked, rel, 2) == 0.5
    assert recall_at_k(ranked, rel, 5) == 1.0
    assert precision_at_k(ranked, rel, 2) == 0.5
    assert mrr(ranked, rel) == 0.5
    # dcg = 1/log2(3) + 1/log2(6); idcg = 1 + 1/log2(3)
    expected = (1 / np.log2(3) + 1 / np.log2(6)) / (1 + 1 / np.log2(3))
    assert abs(ndcg_at_k(ranked, rel, 5) - expected) < 1e-9


def test_empty_relevant():
    assert recall_at_k([0, 1], [], 2) == 0.0
    assert ndcg_at_k([0, 1], [], 2) == 0.0
    assert mrr([0, 1], []) == 0.0


@given(
    st.integers(10, 40),  # n_tools
    st.integers(1, 5),  # n_rel
    st.integers(0, 10_000),  # seed
)
@settings(max_examples=50, deadline=None)
def test_metric_bounds_and_perfect_ranking(n_tools, n_rel, seed):
    rng = np.random.default_rng(seed)
    rel = set(rng.choice(n_tools, size=n_rel, replace=False).tolist())
    ranked = list(rng.permutation(n_tools))
    m = evaluate_ranking(ranked, rel)
    for k, v in m.items():
        assert 0.0 <= v <= 1.0, (k, v)
    # perfect ranking: relevant first
    perfect = sorted(ranked, key=lambda t: t not in rel)
    mp = evaluate_ranking(perfect, rel)
    assert mp["mrr"] == 1.0
    assert mp[f"ndcg@5"] == pytest.approx(1.0)
    assert mp["recall@5"] >= m["recall@5"] - 1e-12


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_batched_matches_scalar(seed):
    rng = np.random.default_rng(seed)
    q, t, k = 8, 20, 5
    relevance = (rng.random((q, t)) < 0.15).astype(np.float32)
    scores = rng.random((q, t)).astype(np.float32)
    rankings = np.argsort(-scores, axis=1)[:, :k]
    b_rec = float(batched_recall_at_k(jnp.asarray(rankings), jnp.asarray(relevance)))
    b_ndcg = float(batched_ndcg_at_k(jnp.asarray(rankings), jnp.asarray(relevance)))
    recs, ndcgs = [], []
    for j in range(q):
        rel = set(np.flatnonzero(relevance[j]).tolist())
        if not rel:
            continue
        recs.append(recall_at_k(rankings[j], rel, k))
        ndcgs.append(ndcg_at_k(rankings[j], rel, k))
    if recs:
        assert b_rec == pytest.approx(np.mean(recs), abs=1e-5)
        assert b_ndcg == pytest.approx(np.mean(ndcgs), abs=1e-5)
