"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
variant, one forward + one train step on CPU, asserting shapes + no NaNs;
plus prefill/decode consistency with the teacher-forced forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import model as M
from repro.models.config import reduced
from repro.training.train_step import TrainConfig, make_train_step

ARCH_IDS = sorted(ARCHITECTURES)


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}
    if cfg.cross_attn_every:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = reduced(ARCHITECTURES[arch])
    params = M.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    b, s = batch["tokens"].shape[:2]
    if cfg.n_codebooks:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(ARCHITECTURES[arch])
    step_fn, opt = make_train_step(cfg, TrainConfig(optimizer="adamw"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    new_params, _, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    deltas = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(deltas)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(ARCHITECTURES[arch])
    params = M.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    batch = _batch(cfg, b, s, seed=1)
    full_logits, _ = M.forward(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : s - 1]
    pl_, cache = M.prefill(cfg, params, pre)
    dl, _ = M.decode_step(
        cfg, params, cache,
        {"token": batch["tokens"][:, s - 1 : s], "pos": jnp.asarray(s - 1, jnp.int32)},
    )
    np.testing.assert_allclose(
        np.asarray(full_logits[:, s - 2 : s - 1]), np.asarray(pl_), atol=2e-2
    )
    np.testing.assert_allclose(
        np.asarray(full_logits[:, s - 1 : s]), np.asarray(dl), atol=2e-2
    )


def test_multi_step_decode_matches_forward():
    """Several consecutive decode steps stay consistent (ring-cache update)."""
    cfg = reduced(ARCHITECTURES["qwen2.5-3b"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    b, s, n_dec = 2, 48, 6
    batch = _batch(cfg, b, s, seed=2)
    full_logits, _ = M.forward(cfg, params, batch)
    pre = {"tokens": batch["tokens"][:, : s - n_dec]}
    _, cache = M.prefill(cfg, params, pre, max_cache_len=s)
    for i in range(n_dec):
        pos = s - n_dec + i
        dl, cache = M.decode_step(
            cfg, params, cache,
            {"token": batch["tokens"][:, pos : pos + 1], "pos": jnp.asarray(pos, jnp.int32)},
        )
        np.testing.assert_allclose(
            np.asarray(full_logits[:, pos : pos + 1]), np.asarray(dl), atol=2e-2
        )


def test_sliding_window_decode_matches_windowed_forward():
    cfg = reduced(ARCHITECTURES["stablelm-3b"], sliding_window=16)
    params = M.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 48
    batch = _batch(cfg, b, s, seed=3)
    full_logits, _ = M.forward(cfg, params, batch)
    pre = {"tokens": batch["tokens"][:, : s - 1]}
    _, cache = M.prefill(cfg, params, pre)
    assert cache["k"].shape[2] == 16  # ring buffer is window-sized
    dl, _ = M.decode_step(
        cfg, params, cache,
        {"token": batch["tokens"][:, s - 1 : s], "pos": jnp.asarray(s - 1, jnp.int32)},
    )
    np.testing.assert_allclose(
        np.asarray(full_logits[:, s - 1 : s]), np.asarray(dl), atol=2e-2
    )


def test_param_counts_match_specs():
    from repro.models.params import param_count

    for arch, cfg in ARCHITECTURES.items():
        spec_n = param_count(M.make_specs(cfg))
        analytic = cfg.param_count()
        assert abs(spec_n - analytic) / analytic < 0.01, (arch, spec_n, analytic)


def test_moe_aux_loss_nonzero():
    cfg = reduced(ARCHITECTURES["dbrx-132b"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    _, aux = M.forward(cfg, params, _batch(cfg))
    assert float(aux) > 0.0
