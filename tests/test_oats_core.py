"""OATS core invariants: Alg. 1 semantics, the validation gate, parameter
counts matching the paper, and the full stage pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import adapter as adapter_lib
from repro.core import reranker as reranker_lib
from repro.core.outcomes import collect_outcomes
from repro.core.pipeline import OATSPipeline, PipelineConfig, STAGE_PRESETS
from repro.core.refine import RefineConfig, refine_embeddings, refine_with_gate
from repro.embedding.bag_encoder import BagEncoder


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _random_world(seed, q=40, t=12, d=32):
    rng = np.random.default_rng(seed)
    qe = _unit(rng.normal(size=(q, d))).astype(np.float32)
    te = _unit(rng.normal(size=(t, d))).astype(np.float32)
    rel = np.zeros((q, t), np.float32)
    rel[np.arange(q), rng.integers(0, t, q)] = 1.0
    return qe, te, rel


def test_outcome_partition_semantics():
    qe, te, rel = _random_world(0)
    logs = collect_outcomes(jnp.asarray(qe), jnp.asarray(te), jnp.asarray(rel), k=5)
    pos = np.asarray(logs.pos_mask)
    neg = np.asarray(logs.neg_mask)
    # positives are exactly the ground-truth pairs ("ground_truth" mode)
    assert (pos == rel).all()
    # negatives only where retrieved and NOT relevant
    assert (neg * rel).sum() == 0
    retrieved = np.asarray(logs.retrieved)
    for j in range(neg.shape[0]):
        for t_id in np.flatnonzero(neg[j]):
            assert t_id in retrieved[j]


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_refined_embeddings_stay_unit_norm(seed):
    qe, te, rel = _random_world(seed)
    hist = refine_embeddings(jnp.asarray(te), jnp.asarray(qe), jnp.asarray(rel))
    final = np.asarray(hist[-1])
    norms = np.linalg.norm(final, axis=-1)
    assert np.allclose(norms, 1.0, atol=1e-5)


def test_refinement_moves_toward_positive_centroid():
    """A tool with a tight positive cluster must move toward it (Eq. 7)."""
    rng = np.random.default_rng(3)
    d = 32
    target = _unit(rng.normal(size=d))
    qe = _unit(target + 0.2 * _unit(rng.normal(size=(12, d)))).astype(np.float32)
    # tool 0 = opaque (far from its queries); tool 1 = decoy
    te = _unit(rng.normal(size=(2, d))).astype(np.float32)
    rel = np.zeros((12, 2), np.float32)
    rel[:, 0] = 1.0
    hist = refine_embeddings(jnp.asarray(te), jnp.asarray(qe), jnp.asarray(rel))
    before = float(qe.mean(0) @ te[0])
    after = float(qe.mean(0) @ np.asarray(hist[-1])[0])
    assert after > before  # pulled toward the positive centroid


def test_validation_gate_never_degrades():
    """Gate invariant (§4.1 step 5): deployed table >= static on val recall."""
    for seed in range(5):
        qe, te, rel = _random_world(seed, q=60)
        tr, va = slice(0, 45), slice(45, 60)
        res = refine_with_gate(
            jnp.asarray(te),
            jnp.asarray(qe[tr]), jnp.asarray(rel[tr]),
            jnp.asarray(qe[va]), jnp.asarray(rel[va]),
            RefineConfig(),
        )
        assert float(res.recall_after) >= float(res.recall_before) or not bool(
            res.accepted
        )
        if not bool(res.accepted):
            # rejected -> table unchanged
            assert np.allclose(np.asarray(res.embeddings), te, atol=1e-6)


def test_gate_rejects_adversarial_refinement():
    """If train labels are adversarial (shuffled), the gate must reject or at
    least not deploy a worse table."""
    qe, te, rel = _random_world(7, q=80)
    rng = np.random.default_rng(0)
    rel_shuffled = rel.copy()
    rng.shuffle(rel_shuffled, axis=0)  # train labels decorrelated from queries
    res = refine_with_gate(
        jnp.asarray(te),
        jnp.asarray(qe[:60]), jnp.asarray(rel_shuffled[:60]),
        jnp.asarray(qe[60:]), jnp.asarray(rel[60:]),
        RefineConfig(),
    )
    if bool(res.accepted):
        assert float(res.recall_after) >= float(res.recall_before)


def test_paper_parameter_counts():
    """§4.2: MLP [7,64,32,1] = 2,625 params; §4.3: adapter = 197,248."""
    mlp = reranker_lib.init_mlp(jax.random.PRNGKey(0))
    assert reranker_lib.mlp_param_count(mlp) == 2625
    ad = adapter_lib.init_adapter(jax.random.PRNGKey(0))
    assert adapter_lib.adapter_param_count(ad) == 197248


def test_adapter_starts_as_identity():
    ad = adapter_lib.init_adapter(jax.random.PRNGKey(0))
    x = _unit(np.random.default_rng(0).normal(size=(5, 384))).astype(np.float32)
    y = np.asarray(adapter_lib.adapter_apply(ad, jnp.asarray(x)))
    assert np.allclose(x, y, atol=1e-6)


def test_pipeline_stage_presets(small_bench):
    enc = BagEncoder(small_bench.vocab)
    for stage in ("oats-s1", "oats-s2"):
        pipe = OATSPipeline.fit(
            small_bench, PipelineConfig(stages=STAGE_PRESETS[stage]), enc
        )
        test_idx = small_bench.test_idx[:20]
        rk = pipe.rank(
            [small_bench.query_tokens[i] for i in test_idx],
            5,
            small_bench.candidate_mask()[test_idx],
        )
        assert rk.shape == (20, 5)
        # rankings must respect candidate sets
        cand = small_bench.candidate_mask()[test_idx]
        for j in range(20):
            assert cand[j][rk[j]].all()


def test_s1_improves_over_static(small_bench):
    """The paper's core claim, on the dense-outcome benchmark."""
    from repro.core.evaluate import BenchmarkEvaluator

    ev = BenchmarkEvaluator(small_bench)
    se = ev.rankings_for("se").metrics["ndcg@5"]
    s1 = ev.rankings_for("oats-s1").metrics["ndcg@5"]
    assert s1 > se + 0.02, (se, s1)
