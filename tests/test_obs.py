"""Telemetry-plane tests (ISSUE 7 satellite d, plus b's health surface):

* metrics core — log-histogram percentile estimates vs exact numpy,
  bucket-edge semantics, clamp-to-observed-range, registry get-or-create
  and kind-conflict errors, Prometheus/JSON export shapes;
* counter thread-safety under genuinely concurrent `route_batch` traffic
  against one shared registry;
* bounded event-bus ring (dropped counter, seq semantics, re-entrant
  subscribers);
* seeded tracer determinism, tracer ring bound, JSONL export and the
  `repro-obs` report renderer;
* health surface end-to-end — a daemon controller's `last_loop_error` sets
  the snapshot to "error" and clears on recovery (with loop_error /
  loop_recovered published on transitions only), `outcomes_dropped`
  surfaces through counter + bus + degraded health;
* ObsServer HTTP endpoints (/metrics, /health 200 vs 503, /events?since=);
* the `repro.router.latency` re-export compatibility surface.
"""
import json
import threading
import time
import types
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.control import ControllerConfig, OutcomeStore, RefinementController
from repro.obs import (
    EventBus,
    HealthMonitor,
    LogHistogram,
    MetricsRegistry,
    ObsServer,
    RouteTracer,
    TraceSampler,
    get_registry,
)
from repro.obs.report import render_trace_report
from repro.obs.summary import percentile_stats
from repro.router.gateway import SemanticRouter
from repro.router.tooldb import ToolRecord, ToolsDatabase

D = 16  # embedding dim for the hand-rolled fixture router


def _embed(tokens):
    return np.bincount(
        np.asarray(tokens, np.int64) % D, minlength=D
    ).astype(np.float32)


def _embed_batch(token_lists):
    return np.stack([_embed(t) for t in token_lists])


def _make_router(n_tools=12, **kw):
    rng = np.random.default_rng(0)
    records = [ToolRecord(i, f"t{i}", np.arange(3), 0) for i in range(n_tools)]
    table = rng.standard_normal((n_tools, D)).astype(np.float32)
    db = ToolsDatabase(records, table)
    return SemanticRouter(db, _embed, k=3, **kw), db


def _wait_for(cond, timeout_s=5.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


# ------------------------------------------------------------- metrics core


def test_histogram_percentiles_track_numpy():
    rng = np.random.default_rng(7)
    samples = np.exp(rng.normal(loc=0.5, scale=1.2, size=2000))  # ~0.01..50
    h = LogHistogram("lat_ms")
    for v in samples:
        h.record(float(v))
    assert h.count() == len(samples)
    assert h.mean() == pytest.approx(samples.mean())  # exact, not bucketed
    for q in (50.0, 90.0, 99.0):
        exact = float(np.percentile(samples, q))
        est = h.percentile(q)
        # default edges are 10 buckets/decade -> ~26% worst-case relative
        # error; allow 30% slack
        assert abs(est - exact) / exact < 0.30, (q, est, exact)


def test_histogram_empty_and_single_sample_clamp():
    h = LogHistogram("x")
    assert h.percentile(50.0) == 0.0
    s = h.summary()
    assert s["count"] == 0 and s["min"] == 0.0 and s["max"] == 0.0
    h.record(3.7)
    # bucket interpolation is clamped to the observed [min, max]: one sample
    # reports that sample at every percentile, never a bucket edge
    assert h.percentile(50.0) == pytest.approx(3.7)
    assert h.percentile(99.0) == pytest.approx(3.7)
    assert h.summary()["min"] == pytest.approx(3.7)
    assert h.summary()["max"] == pytest.approx(3.7)


def test_histogram_bucket_edge_semantics():
    # searchsorted(side="left"): a value exactly on edge i lands in bucket i
    h = LogHistogram("x", edges=np.array([1.0, 2.0, 4.0]))
    h.record(2.0)  # == edges[1]
    h.record(0.5)  # below lo -> underflow bucket 0
    h.record(5.0)  # above hi -> overflow bucket len(edges)
    counts = h.bucket_counts()
    assert len(counts) == 4  # len(edges) + 1 (overflow)
    np.testing.assert_array_equal(counts, [1, 1, 0, 1])


def test_registry_get_or_create_and_kind_conflicts():
    reg = MetricsRegistry()
    a = reg.histogram("route_phase_ms", phase="embed")
    assert reg.histogram("route_phase_ms", phase="embed") is a
    assert reg.histogram("route_phase_ms", phase="score") is not a
    # label order must not matter for identity
    c1 = reg.counter("c", a="1", b="2")
    assert reg.counter("c", b="2", a="1") is c1
    # one kind per metric name, across label sets
    with pytest.raises(ValueError):
        reg.gauge("route_phase_ms")
    with pytest.raises(ValueError):
        reg.histogram("c")


def test_prometheus_rendering_cumulative_buckets():
    reg = MetricsRegistry()
    reg.counter("hits_total").inc(3)
    reg.gauge("table_version").set(5)
    h = reg.histogram("lat_ms", edges=np.array([1.0, 10.0, 100.0]))
    for v in (0.5, 2.0, 2.0, 50.0, 500.0):
        h.record(v)
    text = reg.render_prometheus()
    lines = text.splitlines()
    assert "# TYPE hits_total counter" in lines
    assert "hits_total 3.0" in lines
    assert "table_version 5.0" in lines
    assert "# TYPE lat_ms histogram" in lines
    # cumulative exposition: each bucket includes everything below it
    assert 'lat_ms_bucket{le="1"} 1' in lines
    assert 'lat_ms_bucket{le="10"} 3' in lines
    assert 'lat_ms_bucket{le="100"} 4' in lines
    assert 'lat_ms_bucket{le="+Inf"} 5' in lines
    assert "lat_ms_sum 554.5" in lines
    assert "lat_ms_count 5" in lines


def test_prometheus_label_values_are_escaped():
    # text-format spec: label values escape backslash, double-quote, and
    # newline (regression: these were emitted raw, producing an exposition
    # a scraper rejects — or worse, silently mis-parses into wrong series)
    reg = MetricsRegistry()
    reg.counter("odd_total", path='a"b\\c\nd').inc()
    text = reg.render_prometheus()
    assert 'odd_total{path="a\\"b\\\\c\\nd"} 1.0' in text.splitlines()
    # backslash is escaped first, so a literal backslash-n label value stays
    # distinct from a real newline after escaping
    reg.counter("odd_total", path="\\n").inc()
    text = reg.render_prometheus()
    assert 'odd_total{path="\\\\n"} 1.0' in text.splitlines()
    assert 'odd_total{path="a\\"b\\\\c\\nd"} 1.0' in text.splitlines()
    assert len(reg.instruments()) == 2


def test_snapshot_shape_and_label_keys():
    reg = MetricsRegistry()
    reg.counter("n_total").inc()
    reg.histogram("ms", phase="embed").record(1.0)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["n_total"] == 1.0
    summary = snap["histograms"]['ms{phase="embed"}']
    assert summary["count"] == 1
    assert set(summary) == {"count", "mean", "p50", "p90", "p99", "min", "max"}


def test_default_registry_is_process_wide():
    assert get_registry() is get_registry()


# ------------------------------------- counters under concurrent route_batch


def test_counters_exact_under_concurrent_route_batch():
    reg = MetricsRegistry()
    router, db = _make_router(metrics=reg)
    n_threads, n_calls, batch = 8, 25, 4
    queries = [np.arange(j, j + 4) for j in range(batch)]
    errors = []

    def worker():
        try:
            for _ in range(n_calls):
                router.route_batch(queries)
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = n_threads * n_calls
    assert reg.counter("route_batches_total").value() == total
    assert reg.counter("route_requests_total").value() == total * batch
    assert reg.histogram("route_batch_ms").count() == total
    for phase in ("embed", "adapter", "score", "assemble"):
        assert reg.histogram("route_phase_ms", phase=phase).count() == total
    # no Stage-2 MLP configured: slice-only "reranks" must not be recorded
    assert reg.histogram("route_phase_ms", phase="rerank").count() == 0
    assert reg.gauge("route_table_version").value() == db.table_version


# ------------------------------------------------------------------ EventBus


def test_event_bus_ring_bounds_and_seq_semantics():
    bus = EventBus(capacity=4)
    for i in range(10):
        bus.publish("tick", plane="serve", i=i)
    assert len(bus) == 4
    assert bus.dropped == 6
    assert bus.counts() == {"tick": 10}  # lifetime counts survive eviction
    seqs = [e.seq for e in bus.events()]
    assert seqs == [6, 7, 8, 9]
    assert [e.seq for e in bus.events(since_seq=7)] == [8, 9]
    assert bus.events(kind="other") == []
    last = bus.last("tick")
    assert last is not None and last.seq == 9 and last.details["i"] == 9
    assert bus.last("other") is None
    d = last.as_dict()
    assert d["kind"] == "tick" and d["plane"] == "serve" and d["i"] == 9


def test_event_bus_subscriber_may_publish_without_deadlock():
    bus = EventBus()
    bus.subscribe(
        lambda e: bus.publish("echo", plane=e.plane) if e.kind == "ping" else None
    )
    bus.publish("ping", plane="control")
    assert bus.counts() == {"ping": 1, "echo": 1}


# -------------------------------------------------------------------- tracer


def test_trace_sampler_seeded_determinism():
    a = TraceSampler(sample_every=8, seed=42)
    b = TraceSampler(sample_every=8, seed=42)
    seq_a = [a.sample() for _ in range(400)]
    seq_b = [b.sample() for _ in range(400)]
    assert seq_a == seq_b  # same seed + sequence -> identical decisions
    c = TraceSampler(sample_every=8, seed=43)
    assert [c.sample() for _ in range(400)] != seq_a
    # ~1-in-8 Bernoulli: loose bounds, deterministic given the fixed seed
    assert 20 <= sum(seq_a) <= 90
    always = TraceSampler(sample_every=1, seed=0)
    assert all(always.sample() for _ in range(32))


def test_tracer_ring_export_and_report(tmp_path):
    tracer = RouteTracer(sample_every=1, capacity=8, seed=0)
    router, _ = _make_router(metrics=False, tracer=tracer)
    for i in range(12):
        router.route_batch([np.arange(i, i + 3), np.arange(i + 1, i + 4)])
    assert len(tracer) == 8
    assert tracer.dropped == 4
    traces = tracer.traces()
    t = traces[-1]
    assert t.batch_size == 2 and t.bucket == 2  # pow2 bucket of Q=2
    assert t.path == "index:dense"
    phases = [name for name, _ in t.spans]
    assert phases == ["embed", "adapter", "score", "assemble"]  # no MLP
    assert t.total_ms >= sum(ms for _, ms in t.spans) * 0.5
    assert "embed" in tracer.phase_summaries()

    out = tmp_path / "trace.jsonl"
    assert tracer.export_jsonl(str(out)) == 8
    records = [json.loads(line) for line in out.read_text().splitlines()]
    assert len(records) == 8 and records[0]["spans"].keys() == set(phases)
    report = render_trace_report(records)
    assert "8 traces" in report
    assert "index:dense=8" in report
    assert "embed" in report and "total" in report
    assert render_trace_report([]) == "no traces\n"


# ------------------------------------------------------------ health surface


def test_loop_error_sets_health_and_clears_on_recovery():
    bus = EventBus()
    router, db = _make_router(metrics=False)
    store = OutcomeStore(n_tools=len(db), capacity=256)
    controller = RefinementController(
        db,
        store,
        _embed_batch,
        routers=[router],
        config=ControllerConfig(min_events=10**9, max_interval_s=10**9),
        bus=bus,
    )
    monitor = HealthMonitor(routers=[router], controllers=[controller], bus=bus)

    def boom():
        raise RuntimeError("injected step failure")

    controller.step = boom  # shadow the bound method; deleted to recover
    controller.start(interval_s=0.01)
    try:
        assert _wait_for(lambda: bus.last("loop_error") is not None)
        snap = monitor.snapshot()
        assert snap["status"] == "error" and snap["ok"] is False
        assert "injected step failure" in snap["control"][0]["last_loop_error"]

        del controller.step  # next daemon tick runs the real (healthy) step
        assert _wait_for(lambda: bus.last("loop_recovered") is not None)
        assert _wait_for(lambda: controller.last_loop_error is None)
        snap = monitor.snapshot()
        assert snap["status"] == "ok" and snap["ok"] is True
        assert snap["control"][0]["last_loop_error"] is None
    finally:
        controller.stop()
    # transitions only: one error event and one recovery, not one per tick
    assert bus.counts()["loop_error"] == 1
    assert bus.counts()["loop_recovered"] == 1


def test_outcomes_dropped_surfaces_through_counter_bus_and_health():
    reg = MetricsRegistry()
    bus = EventBus()
    router, _ = _make_router(metrics=reg, bus=bus, outcome_capacity=2)
    for i in range(5):
        router.record_outcome(np.arange(3), tool_id=i % 3, outcome=1)
    assert router.outcomes_dropped == 3
    assert reg.counter("route_outcomes_dropped_total").value() == 3
    # the bus sees the first drop only (a transition, not a per-event spam)
    drops = bus.events(kind="outcomes_dropping")
    assert len(drops) == 1 and drops[0].details["dropped"] == 1
    snap = HealthMonitor(routers=[router], bus=bus).snapshot()
    assert snap["status"] == "degraded" and snap["ok"] is True
    assert snap["serving"][0]["outcomes_dropped"] == 3
    assert snap["events"]["counts"]["outcomes_dropping"] == 1


def test_health_snapshot_ok_with_healthy_planes():
    bus = EventBus()
    router, db = _make_router(metrics=False, bus=bus)
    bus.watch_db(db)
    store = OutcomeStore(n_tools=len(db), capacity=256)
    monitor = HealthMonitor(
        routers=[router], indexes=[router.index], stores=[store], bus=bus
    )
    router.route_batch([np.arange(3)])
    snap = monitor.snapshot()
    assert snap["status"] == "ok"
    assert snap["serving"][0]["table_version"] == db.table_version
    assert snap["index"][0]["fresh"] is True
    assert snap["stores"][0] == {
        "n_events": 0, "dropped": 0, "total_ingested": 0,
    }


# ----------------------------------------------------------------- ObsServer


def _get(url):
    with urllib.request.urlopen(url, timeout=5) as resp:
        return resp.status, resp.read().decode()


def test_obs_server_endpoints():
    reg = MetricsRegistry()
    bus = EventBus()
    router, _ = _make_router(metrics=reg, bus=bus)
    router.route_batch([np.arange(3), np.arange(4)])
    bus.publish("tick", plane="serve")
    monitor = HealthMonitor(routers=[router], bus=bus)
    server = ObsServer(monitor, reg, bus).start()
    base = f"http://{server.host}:{server.port}"
    try:
        code, text = _get(base + "/metrics")
        assert code == 200
        assert "# TYPE route_batches_total counter" in text
        assert "route_phase_ms_bucket" in text

        code, text = _get(base + "/health")
        snap = json.loads(text)
        assert code == 200 and snap["status"] == "ok"

        code, text = _get(base + "/events?since=-1")
        assert code == 200
        kinds = [e["kind"] for e in json.loads(text)]
        assert "tick" in kinds

        with pytest.raises(urllib.error.HTTPError) as err:
            _get(base + "/nope")
        assert err.value.code == 404
    finally:
        server.stop()


def test_obs_server_health_returns_503_on_loop_error():
    failing = types.SimpleNamespace(
        last_loop_error=RuntimeError("dead loop"), reports=[]
    )
    server = ObsServer(HealthMonitor(controllers=[failing])).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as err:
            _get(f"http://{server.host}:{server.port}/health")
        assert err.value.code == 503
        snap = json.loads(err.value.fp.read())
        assert snap["status"] == "error"
        assert "dead loop" in snap["control"][0]["last_loop_error"]
    finally:
        server.stop()


# ------------------------------------------------------- latency re-exports


def test_router_latency_reexports_obs_summary():
    from repro.obs import summary
    from repro.router import latency

    # satellite (a): one percentile implementation, re-exported for compat
    assert latency.percentile_stats is summary.percentile_stats
    assert latency.LatencyStats is summary.LatencyStats
    stats = latency.percentile_stats([1.0, 2.0, 3.0])
    assert stats.p50_ms == 2.0 and stats.n == 3
    assert set(stats.as_dict()) == {"p50_ms", "p99_ms", "mean_ms", "n"}
    measured = latency.measure_latency(lambda i: i, n_requests=5, warmup=1)
    assert isinstance(measured, latency.LatencyStats) and measured.n == 5
