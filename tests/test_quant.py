"""Int8 weight quantization: roundtrip quality + decode-logit fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHITECTURES
from repro.models import model as M
from repro.models.config import reduced
from repro.models.quant import (
    dequantize_tree,
    quantize_tree,
    quantized_bytes,
    should_quantize,
)


def test_should_quantize_policy():
    assert should_quantize((512, 512))
    assert should_quantize((32, 2048, 128))
    assert not should_quantize((512,))  # norms
    assert not should_quantize((32, 8))  # tiny projections


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_roundtrip_error_bounded(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32))
    q = quantize_tree({"w": w})
    back = dequantize_tree(q, jnp.float32)["w"]
    # per-channel int8: rounding <= scale/2, plus <= scale/2 from the bf16
    # scale storage (2^-8 relative x |q|<=127) => 1 quantum total
    col_max = np.abs(np.asarray(w)).max(axis=0)
    assert (np.abs(np.asarray(back - w)) <= col_max[None, :] / 127 + 1e-6).all()


def test_quantized_decode_logits_close():
    cfg = reduced(ARCHITECTURES["qwen2.5-3b"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :31]}, max_cache_len=32)
    dec = {"token": toks[:, 31:32], "pos": jnp.asarray(31, jnp.int32)}
    l_ref, _ = M.decode_step(cfg, params, cache, dec)
    qp = quantize_tree(params)
    l_q, _ = M.decode_step(cfg, dequantize_tree(qp, jnp.float32), cache, dec)
    # logits shift a little, but top-1 agrees (what serving needs) — except
    # where the reference top-1/top-2 gap sits inside the int8 noise band,
    # where the order may legitimately flip (platform reduction order decides)
    ref = l_ref.reshape(l_ref.shape[0], -1)
    q = l_q.reshape(l_q.shape[0], -1)
    agree = jnp.argmax(ref, -1) == jnp.argmax(q, -1)
    top2_val, top2_idx = jax.lax.top_k(ref, 2)
    gap = top2_val[:, 0] - top2_val[:, 1]
    # noise at the two COMPETING positions only — a large error on some
    # unrelated logit must not excuse a genuine top-1 flip — and the excuse
    # only applies when the flip IS to the reference runner-up
    noise = jnp.max(
        jnp.abs(jnp.take_along_axis(ref - q, top2_idx, axis=-1)), axis=-1
    )
    flipped_to_runner_up = jnp.argmax(q, -1) == top2_idx[:, 1]
    excused = flipped_to_runner_up & (gap <= 2 * noise)
    assert bool(jnp.all(agree | excused)), (agree, gap, noise)
    rel = float(jnp.max(jnp.abs(l_ref - l_q)) / jnp.max(jnp.abs(l_ref)))
    assert rel < 0.1, rel


def test_quantized_bytes_halves_weights():
    cfg = ARCHITECTURES["granite-3-8b"]
    specs = M.make_specs(cfg)
    qb = quantized_bytes(specs)
    fb = 2 * cfg.param_count()
    assert qb < 0.6 * fb  # ~2x smaller (scales overhead ~1%)
