"""Router serving-plane tests: table swap/rollback, route semantics, outcome
logging, end-to-end refinement cycle through the gateway."""
import numpy as np
import pytest

from repro.core.pipeline import OATSPipeline, PipelineConfig, STAGE_PRESETS
from repro.embedding.bag_encoder import BagEncoder
from repro.router.gateway import SemanticRouter
from repro.router.latency import measure_latency
from repro.router.tooldb import ToolRecord, ToolsDatabase


def _db_and_encoder(bench):
    enc = BagEncoder(bench.vocab)
    records = [
        ToolRecord(i, f"tool_{i}", bench.desc_tokens[i], int(bench.tool_category[i]))
        for i in range(bench.n_tools)
    ]
    return ToolsDatabase(records, enc.encode(bench.desc_tokens)), enc


def test_swap_and_rollback(small_bench):
    db, enc = _db_and_encoder(small_bench)
    orig = db.embeddings.copy()
    v0 = db.table_version
    new = np.roll(orig, 1, axis=0)
    db.swap_table(new)
    assert db.table_version == v0 + 1
    np.testing.assert_array_equal(db.embeddings, new)
    db.rollback()
    np.testing.assert_array_equal(db.embeddings, orig)
    with pytest.raises(RuntimeError):
        db.rollback()  # version history exhausted
    with pytest.raises(AssertionError):
        db.swap_table(np.zeros((3, 3), np.float32))  # shape guard


def test_route_returns_topk_by_similarity(small_bench):
    db, enc = _db_and_encoder(small_bench)
    router = SemanticRouter(db, embed_fn=lambda t: enc.encode_one(t), k=5)
    q = small_bench.query_tokens[0]
    res = router.route(q)
    assert len(res.tools) == 5
    sims = db.embeddings @ enc.encode_one(q)
    expected = np.argsort(-sims)[:5]
    assert set(res.tools) == set(int(t) for t in expected)
    assert res.scores == sorted(res.scores, reverse=True)
    assert res.latency_ms > 0


def test_outcome_cycle_improves_recall(small_bench):
    """Full control-plane cycle: route -> log outcomes -> refine -> swap ->
    recall@5 on held-out queries does not degrade and typically improves."""
    import jax.numpy as jnp

    from repro.core.refine import RefineConfig, refine_with_gate

    b = small_bench
    db, enc = _db_and_encoder(b)
    router = SemanticRouter(db, embed_fn=lambda t: enc.encode_one(t), k=5)

    def recall(idx):
        hits = 0
        for qi in idx:
            res = router.route(b.query_tokens[qi])
            hits += int(b.relevant[qi][0] in res.tools)
        return hits / len(idx)

    test_idx = b.test_idx[:60]
    before = recall(test_idx)

    # serve the training stream, logging outcomes
    for qi in b.train_idx:
        res = router.route(b.query_tokens[qi])
        for t in res.tools:
            router.record_outcome(b.query_tokens[qi], t, int(t in b.relevant[qi]))
    events = router.drain_outcomes()
    assert len(events) == len(b.train_idx) * 5
    assert len(router.outcome_log) == 0

    # offline refinement from the logged outcomes (production shape of Alg. 1)
    rel = b.relevance_matrix()
    tr = b.train_idx[: int(0.85 * len(b.train_idx))]
    va = b.train_idx[int(0.85 * len(b.train_idx)) :]
    qe = enc.encode(b.query_tokens)
    res = refine_with_gate(
        jnp.asarray(db.embeddings),
        jnp.asarray(qe[tr]), jnp.asarray(rel[tr]),
        jnp.asarray(qe[va]), jnp.asarray(rel[va]),
        RefineConfig(),
    )
    db.swap_table(np.asarray(res.embeddings))
    after = recall(test_idx)
    assert after >= before - 0.02  # gate guarantee (tolerance for split noise)
    if bool(res.accepted):
        assert after >= before


def test_latency_harness_measures():
    stats = measure_latency(lambda i: sum(range(100)), n_requests=50, warmup=5)
    assert stats.n == 50
    assert stats.p99_ms >= stats.p50_ms > 0
