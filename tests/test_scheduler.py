"""Continuous-batching scheduler + tokenizer tests."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.embedding.tokenizer import HashTokenizer
from repro.models import model as M
from repro.models.config import reduced
from repro.router.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(ARCHITECTURES["qwen2.5-3b"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n, rng, max_new=4):
    out = []
    for i in range(n):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)
        out.append(Request(request_id=i, prompt=prompt, max_new_tokens=max_new))
    return out


def test_batcher_drains_all_requests(small_lm):
    cfg, params = small_lm
    rng = np.random.default_rng(0)
    b = ContinuousBatcher(cfg, params, n_slots=3, max_len=32)
    reqs = _reqs(cfg, 7, rng)
    for r in reqs:
        b.submit(r)
    done = b.run_until_drained(max_ticks=200)
    assert len(done) == 7
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert r.admitted_at_tick >= 0 and r.finished_at_tick >= r.admitted_at_tick


def test_batcher_overlaps_requests(small_lm):
    """Continuous batching must run multiple requests concurrently."""
    cfg, params = small_lm
    rng = np.random.default_rng(1)
    b = ContinuousBatcher(cfg, params, n_slots=4, max_len=32)
    for r in _reqs(cfg, 4, rng, max_new=6):
        b.submit(r)
    stats = b.tick()
    assert stats["active"] == 4  # all admitted in one tick
    done = b.run_until_drained(max_ticks=100)
    # with 4 slots and 4 requests everything finishes in ~6 ticks, not 24
    assert b.tick_count <= 12
    assert len(done) == 4


def test_batcher_matches_sequential_decode(small_lm):
    """A single request through the batcher == plain prefill+decode loop."""
    import jax.numpy as jnp

    cfg, params = small_lm
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)

    b = ContinuousBatcher(cfg, params, n_slots=2, max_len=32)
    b.submit(Request(request_id=0, prompt=prompt, max_new_tokens=5))
    (done,) = b.run_until_drained()

    logits, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt[None])}, max_cache_len=32)
    tok = int(jnp.argmax(logits[0, -1]))
    ref = [tok]
    pos = len(prompt)
    cur = jnp.asarray([[tok]], jnp.int32)
    for _ in range(4):
        lg, cache = M.decode_step(cfg, params, cache, {"token": cur, "pos": jnp.asarray(pos, jnp.int32)})
        tok = int(jnp.argmax(lg[0, -1]))
        ref.append(tok)
        cur = jnp.asarray([[tok]], jnp.int32)
        pos += 1
    assert done.generated == ref


def test_hash_tokenizer(small_bench):
    tok = HashTokenizer(small_bench.vocab)
    tok.register_tool_names([f"tool_{i}" for i in range(small_bench.n_tools)])
    a = tok.encode("please use tool_3 to fetch the report")
    b = tok.encode("please use tool_3 to fetch the report")
    assert (a == b).all()  # deterministic
    assert small_bench.vocab.name_token(3) in a  # registered name resolves
    c = tok.encode("completely different words entirely")
    assert not np.array_equal(a, c)
    # unknown words land in the stopword band
    sb = small_bench.vocab.stop_block
    unknown = tok.encode("zzzqqq")
    assert sb <= unknown[0] < sb + small_bench.vocab.n_stop
