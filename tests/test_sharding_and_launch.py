"""Sharding rules, input specs, state specs, HLO analysis, traffic model.

These run with 1 CPU device (the 512-device mesh is exercised only by
`python -m repro.launch.dryrun`); rule resolution is tested against
synthetic mesh axis descriptions, and a real 1-device lowering proves the
model code path is mesh-agnostic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.sharding import spec_for
from repro.configs import get_config
from repro.launch.hlo_analysis import CollectiveStats, parse_collectives, roofline_terms
from repro.launch.specs import SHAPES, input_specs, variant_for_shape
from repro.launch.state_specs import opt_state_structs
from repro.launch.hbm_model import analytic_hbm_bytes
from repro.models import model as M
from repro.models.config import reduced
from repro.models.params import param_structs


MESH_AXES = ("pod", "data", "model")
SIZES = {"pod": 2, "data": 16, "model": 16}


def test_spec_resolution_basic():
    assert spec_for(("batch", None, "heads", None), MESH_AXES) == P(
        ("pod", "data"), None, "model", None
    )
    # single-pod mesh: "pod" silently drops
    assert spec_for(("batch", None), ("data", "model")) == P("data", None)


def test_divisibility_drops_axis():
    # kv_heads=8 cannot shard over model=16 -> replicated
    spec = spec_for(("layers", "embed", "kv_heads", None), MESH_AXES, (32, 4096, 8, 128), SIZES)
    assert spec == P(None, "data", None, None)
    # but 32 kv heads shard fine
    spec = spec_for(("layers", "embed", "kv_heads", None), MESH_AXES, (32, 4096, 32, 128), SIZES)
    assert spec == P(None, "data", "model", None)
    # odd vocab replicates
    spec = spec_for(("vocab", "embed"), MESH_AXES, (49155, 4096), SIZES)
    assert spec == P(None, "data")


def test_input_specs_cover_all_shapes():
    for arch in ("qwen2.5-3b", "musicgen-medium", "llama-3.2-vision-90b", "mamba2-2.7b"):
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            c = variant_for_shape(cfg, shape)
            specs = input_specs(c, shape)
            if shape.kind in ("train", "prefill"):
                toks = specs["tokens"]
                assert toks.shape[0] == shape.global_batch
                assert toks.shape[1] == shape.seq_len
                if c.cross_attn_every:
                    assert "image_embeds" in specs
            else:
                assert specs["token"].shape[:2] == (shape.global_batch, 1)


def test_long_context_variant_policy():
    long = SHAPES["long_500k"]
    # SSM/hybrid: native (no window added)
    assert variant_for_shape(get_config("mamba2-2.7b"), long).sliding_window == 0
    assert variant_for_shape(get_config("hymba-1.5b"), long).sliding_window == 1024
    # dense: explicit sliding-window variant
    v = variant_for_shape(get_config("qwen2.5-3b"), long)
    assert v.sliding_window == 8192 and v.name.endswith("+swa")
    # decode_32k unchanged (full attention is allowed there)
    assert variant_for_shape(get_config("qwen2.5-3b"), SHAPES["decode_32k"]).sliding_window == 0


def test_opt_state_structs_match_runtime():
    """Dry-run optimizer structs must exactly match optimizer.init shapes."""
    from repro import optim

    cfg = reduced(get_config("granite-3-8b"))
    specs = M.make_specs(cfg)
    params = M.init(cfg, jax.random.PRNGKey(0))
    for name, opt in [("adamw", optim.adamw(1e-3)), ("adafactor", optim.adafactor(1e-3))]:
        structs = opt_state_structs(name, specs, mesh=None)
        real = opt.init(params)
        s_shapes = [x.shape for x in jax.tree.leaves(structs)]
        r_shapes = [x.shape for x in jax.tree.leaves(real)]
        assert s_shapes == r_shapes, name


def test_collective_parser():
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups=...
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %aa = (f32[8,64]{1,0}, f32[8,64]{1,0}) all-to-all(%a, %b), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z), source_target_pairs=...
  %nn = f32[2,2]{1,0} add(%p, %q)
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_type["all-gather"] == 1
    assert stats.bytes_by_type["all-gather"] == 16 * 512 * 2
    assert stats.bytes_by_type["all-reduce"] == 1024 * 4
    assert stats.bytes_by_type["all-to-all"] == 2 * 8 * 64 * 4
    assert stats.bytes_by_type["collective-permute"] == 16 * 2
    # all-reduce weighted 2x on the wire
    assert stats.wire_bytes == pytest.approx(
        2 * 1024 * 4 + 16 * 512 * 2 + 2 * 8 * 64 * 4 + 16 * 2
    )


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, 0.0)  # exactly 1s of compute
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(0.0, 819e9, 0.0)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(1.0)
    t = roofline_terms(0.0, 0.0, 200e9)
    assert t["dominant"] == "collective" and t["collective_s"] == pytest.approx(1.0)


def test_traffic_model_decode_is_weight_dominated():
    cfg = get_config("qwen2.5-3b")
    tr = analytic_hbm_bytes(cfg, "decode", 128, 32768, 256, 16)
    assert tr["weights"] > 0 and tr["cache_read"] > 0
    # windowed variant shrinks cache traffic by ~seq/window
    v = variant_for_shape(cfg, SHAPES["long_500k"])
    tr_l = analytic_hbm_bytes(v, "decode", 1, 524288, 256, 16)
    full = analytic_hbm_bytes(cfg, "decode", 1, 524288, 256, 16)
    assert tr_l["cache_read"] < full["cache_read"] / 10


def test_single_device_lowering_smoke():
    """The dry-run program shape lowers on the local 1-device 'mesh' too."""
    cfg = reduced(get_config("qwen2.5-3b"))
    specs = M.make_specs(cfg)
    pstructs = param_structs(specs, dtype=jnp.float32)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    lowered = jax.jit(lambda p, b: M.loss_fn(cfg, p, b)[0]).lower(pstructs, batch)
    compiled = lowered.compile()
    from repro.common.meshctx import cost_analysis_dict
    assert cost_analysis_dict(compiled)["flops"] > 0
