"""Judgement-layer tests (ISSUE 8: SLO engine + quality observability):

* exemplars — lazy per-bucket slots, most-recent-wins retention, the
  percentile-bucket-then-up-then-down fallback order, and the conditional
  `summary()` key (histograms that never attach exemplars keep the exact
  PR 6 summary shape);
* `record_many` parity with a `record` loop (counts, sum, min/max);
* `TimeSeriesRing` — two-sample window semantics (a single tick yields
  None, never a fabricated zero), counter delta/rate, histogram window
  deltas, empty-window quantiles, synthetic bus counters, daemon
  start/stop with a clean `last_loop_error`;
* `SLOEngine` — burn-rate math vs hand-computed windows for all three SLI
  kinds (latency fraction-over-threshold, counter ratio, event rate), the
  both-windows breach rule, the transition latch (`slo_burn` once per
  entry, `slo_recovered` once per exit), and `HealthMonitor` degrading
  while burning;
* `RollingWindows` — bounded per-key windows, pruning;
* `QualityMonitor` — rolling NDCG/Recall gauges, drift rising-edge +
  re-arm, the `watch_db` reference-follows-swaps contract and its detach
  handle (EventBus.watch_db's detach too);
* HTTP surface — /slo judging live and /traces?id= resolving exemplars;
* `repro-obs` — --since filtering, --follow tailing, --watch panel
  rendering with an exemplar-to-trace link.
"""
import io
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import (
    SLO,
    BurnWindow,
    EventBus,
    HealthMonitor,
    LogHistogram,
    MetricsRegistry,
    ObsServer,
    QualityConfig,
    QualityMonitor,
    RollingWindows,
    RouteTracer,
    SLOEngine,
    TimeSeriesRing,
    default_slos,
)
from repro.obs.report import follow_events, render_watch_panel, watch
from repro.router.tooldb import ToolRecord, ToolsDatabase

D = 16


def _make_db(n_tools=8, seed=0):
    rng = np.random.default_rng(seed)
    records = [ToolRecord(i, f"t{i}", np.arange(3), 0) for i in range(n_tools)]
    return ToolsDatabase(records, rng.standard_normal((n_tools, D)).astype(np.float32))


def _wait_for(cond, timeout_s=5.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(0.005)
    return False


# -------------------------------------------------------------- exemplars


def test_exemplar_slots_lazy_and_most_recent_wins():
    h = LogHistogram("x")
    h.record(5.0)  # no exemplar -> no slots allocated, no retention cost
    assert h.exemplars() == {}
    h.record(5.0, exemplar=7)
    h.record(5.0, exemplar=9)  # same bucket: most recent wins
    ex = h.exemplars()
    assert len(ex) == 1
    (_, (eid, val, ts)), = ex.items()
    assert eid == 9 and val == pytest.approx(5.0) and ts > 0
    # a later exemplar-free record does NOT evict the retained exemplar
    h.record(5.0)
    assert next(iter(h.exemplars().values()))[0] == 9


def test_percentile_exemplar_fallback_order():
    h = LogHistogram("x")
    for _ in range(99):
        h.record(1.0)
    h.record(50.0)  # the p99 sample, in a much higher bucket
    # exemplar only on the low bucket: p99 bucket and everything above it
    # are bare, so the search falls back downward to the low bucket
    h.record(1.0, exemplar=11)
    assert h.percentile_exemplar(99.0)[0] == 11
    # now tag the tail: the p99 bucket itself is preferred over lower ones
    h.record(50.0, exemplar=22)
    assert h.percentile_exemplar(99.0)[0] == 22
    assert h.percentile_exemplar(50.0)[0] == 11  # p50 bucket has its own


def test_summary_exemplar_key_is_conditional():
    h = LogHistogram("x")
    h.record(1.0)
    assert "p99_exemplar" not in h.summary()  # PR 6 shape preserved
    h.record(2.0, exemplar=3)
    assert h.summary()["p99_exemplar"] == 3
    empty = LogHistogram("y")
    assert h.percentile_exemplar(99.0) is not None
    assert empty.percentile_exemplar(99.0) is None  # no samples -> None


def test_record_many_parity_with_record_loop():
    rng = np.random.default_rng(3)
    vals = np.exp(rng.normal(size=500)).astype(np.float32)
    one, many = LogHistogram("a"), LogHistogram("b")
    for v in vals:
        one.record(float(v))
    many.record_many(vals)
    many.record_many(np.empty(0))  # no-op, not an error
    assert many.count() == one.count() == len(vals)
    assert np.array_equal(many._counts, one._counts)
    s1, s2 = one.summary(), many.summary()
    assert s2["mean"] == pytest.approx(s1["mean"], rel=1e-5)
    assert s2["min"] == pytest.approx(s1["min"], rel=1e-6)
    assert s2["max"] == pytest.approx(s1["max"], rel=1e-6)


# ---------------------------------------------------------- timeseries ring


def test_ring_two_sample_window_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total")
    ring = TimeSeriesRing(reg)
    assert ring.window(60.0) is None  # empty ring
    c.inc(5)
    ring.tick(now=0.0)
    # ONE tick: no rate, no delta, no histogram window — never a zero
    assert ring.window(60.0, now=0.0) is None
    assert ring.delta("reqs_total", 60.0, now=0.0) is None
    assert ring.rate("reqs_total", 60.0, now=0.0) is None
    c.inc(10)
    ring.tick(now=10.0)
    assert ring.delta("reqs_total", 60.0, now=10.0) == pytest.approx(10.0)
    assert ring.rate("reqs_total", 60.0, now=10.0) == pytest.approx(1.0)
    # a window too short to contain both ticks is insufficient again
    assert ring.delta("reqs_total", 5.0, now=10.0) is None


def test_ring_histogram_window_and_empty_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    ring = TimeSeriesRing(reg)
    h.record(1.0)
    ring.tick(now=0.0)
    ring.tick(now=10.0)  # nothing recorded in between
    wh = ring.window_hist("lat_ms", 60.0, now=10.0)
    assert wh.count == 0
    assert wh.quantile(99.0) is None  # empty window: no quantile
    assert wh.fraction_gt(10.0) is None  # and no latency SLI
    assert wh.mean() == 0.0
    for v in (5.0, 5.0, 15.0, 25.0):
        h.record(v)
    ring.tick(now=20.0)
    wh = ring.window_hist("lat_ms", 60.0, now=20.0)
    assert wh.count == 4 and wh.sum == pytest.approx(50.0)
    # 10.0 sits on a bucket edge: the fraction is exact, 2 of 4 above
    assert wh.fraction_gt(10.0) == pytest.approx(0.5)
    assert wh.quantile(50.0) is not None


def test_ring_bus_synthetic_counters_and_daemon():
    reg = MetricsRegistry()
    bus = EventBus()
    bus.publish("swap", plane="control", version=1)
    bus.publish("swap", plane="control", version=2)
    ring = TimeSeriesRing(reg, bus=bus)
    p = ring.tick(now=0.0)
    assert p.counters['events_total{kind="swap"}'] == 2.0
    assert p.counters["bus_dropped_total"] == 0.0
    ticks = []
    ring.start(interval_s=0.01, on_tick=lambda r: ticks.append(len(r)))
    assert _wait_for(lambda: len(ring) >= 3)
    ring.stop()
    assert ring.last_loop_error is None
    assert ticks  # the judgement hook ran on the cadence


def test_ring_capacity_bounds_memory():
    reg = MetricsRegistry()
    ring = TimeSeriesRing(reg, capacity=4)
    for i in range(10):
        ring.tick(now=float(i))
    assert len(ring) == 4
    assert ring.points()[0].mono == 6.0  # oldest evicted


# ------------------------------------------------------------- burn math


def _latency_slo(**kw):
    defaults = dict(
        name="lat",
        kind="latency",
        hist_key="lat_ms",
        threshold_ms=10.0,
        objective=0.90,
        windows=(BurnWindow(long_s=120.0, short_s=40.0, factor=1.0),),
    )
    defaults.update(kw)
    return SLO(**defaults)


def test_latency_burn_matches_hand_computed_window():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(_latency_slo(),), registry=reg)
    ring.tick(now=0.0)
    for v in [5.0] * 8 + [15.0] * 2:  # 10 samples, 2 above threshold
        h.record(v)
    ring.tick(now=50.0)
    for _ in range(10):
        h.record(5.0)
    ring.tick(now=90.0)
    snap = engine.evaluate(now=100.0)
    w = snap["slos"]["lat"]["windows"][0]
    # long window [(-20)..100] spans ticks 0..90: 20 samples, 2 bad ->
    # bad_frac 0.1, burn = 0.1 / (1 - 0.90) = 1.0 exactly
    assert w["burn_long"] == pytest.approx(1.0)
    # short window [60..100] holds only the t=90 tick: insufficient -> None
    assert w["burn_short"] is None
    assert not w["breaching"]  # None never alerts
    assert snap["status"] == "ok"
    # the latency entry carries live p99 evidence + gauge updates
    assert snap["slos"]["lat"]["p99_ms"] is not None
    assert reg.gauge("slo_burning", slo="lat").value() == 0.0
    assert reg.gauge("slo_burn_rate", slo="lat").value() == pytest.approx(1.0)


def test_ratio_burn_matches_hand_computed_window():
    reg = MetricsRegistry()
    bad = reg.counter("served_total", path="exact")
    good = reg.counter("served_total", path="index")
    slo = SLO(
        name="fallback",
        kind="ratio",
        bad_keys=('served_total{path="exact"}',),
        total_keys=('served_total{path="exact"}', 'served_total{path="index"}'),
        objective=0.95,
        windows=(BurnWindow(long_s=100.0, short_s=100.0, factor=2.0),),
    )
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(slo,))
    ring.tick(now=0.0)
    bad.inc(5)
    good.inc(95)
    ring.tick(now=50.0)
    snap = engine.evaluate(now=50.0)
    w = snap["slos"]["fallback"]["windows"][0]
    # 5 bad of 100 -> 0.05; burn = 0.05 / (1 - 0.95) = 1.0 < factor 2.0
    assert w["burn_long"] == pytest.approx(1.0)
    assert not snap["slos"]["fallback"]["burning"]


def test_rate_burn_matches_hand_computed_window():
    reg = MetricsRegistry()
    ev = reg.counter("my_events_total")
    slo = SLO(
        name="rollbacks",
        kind="rate",
        event_keys=("my_events_total",),
        max_per_hour=60.0,
        windows=(BurnWindow(long_s=4000.0, short_s=4000.0, factor=1.0),),
    )
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(slo,))
    ring.tick(now=0.0)
    ev.inc(30)
    ring.tick(now=3600.0)
    snap = engine.evaluate(now=3600.0)
    w = snap["slos"]["rollbacks"]["windows"][0]
    # 30 events over exactly one hour vs 60 allowed -> burn 0.5
    assert w["burn_long"] == pytest.approx(0.5)
    assert not snap["slos"]["rollbacks"]["burning"]


def test_breach_requires_both_windows():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(_latency_slo(),))
    ring.tick(now=0.0)
    for _ in range(10):
        h.record(15.0)  # all bad
    ring.tick(now=50.0)
    # long window burns (burn 10 > 1) but the short window has one tick:
    # evidence without "still happening" is not a breach
    snap = engine.evaluate(now=50.0)
    assert not snap["slos"]["lat"]["burning"]
    ring.tick(now=70.0)
    h.record(15.0)
    ring.tick(now=95.0)  # two ticks inside [55..95]: short window forms
    snap = engine.evaluate(now=95.0)
    assert snap["slos"]["lat"]["burning"]
    assert snap["status"] == "burning" and snap["burning"] == ["lat"]


def test_transition_latch_publishes_each_edge_once():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    bus = EventBus()
    ring = TimeSeriesRing(reg)
    slo = _latency_slo(windows=(BurnWindow(100.0, 100.0, 1.0),))
    engine = SLOEngine(ring, slos=(slo,), bus=bus, registry=reg)
    monitor = HealthMonitor(slo=engine)

    ring.tick(now=0.0)
    for _ in range(10):
        h.record(15.0)
    ring.tick(now=50.0)
    engine.evaluate(now=50.0)
    assert bus.counts().get("slo_burn") == 1
    d = bus.last("slo_burn").details
    assert d["slo"] == "lat" and d["sli"] == "latency"
    assert d["threshold_ms"] == 10.0 and d["burn"] == pytest.approx(10.0)
    # burning SLO degrades health (without re-judging: burning() is a read)
    snap = monitor.snapshot()
    assert snap["status"] == "degraded" and snap["slo"]["burning"] == ["lat"]
    assert reg.gauge("slo_burning", slo="lat").value() == 1.0

    # still breaching: the latch holds, no second event
    ring.tick(now=60.0)
    engine.evaluate(now=60.0)
    assert bus.counts().get("slo_burn") == 1
    assert engine.burning() == ["lat"]

    # the bad samples age out of the window: recovery fires exactly once
    ring.tick(now=500.0)
    ring.tick(now=560.0)
    engine.evaluate(now=560.0)
    assert bus.counts().get("slo_recovered") == 1
    assert bus.last("slo_recovered").details["slo"] == "lat"
    assert engine.burning() == []
    assert monitor.snapshot()["status"] == "ok"
    engine.evaluate(now=570.0)
    assert bus.counts().get("slo_recovered") == 1  # no flapping


def test_default_slos_cover_the_catalog_and_stay_quiet_without_data():
    names = [s.name for s in default_slos()]
    assert names == [
        "route_p99_budget",
        "exact_fallback_ratio",
        "guard_rollback_rate",
        "drop_rate",
        "jit_retrace_rate",
        "cache_staleness",
    ]
    reg = MetricsRegistry()
    engine = SLOEngine(TimeSeriesRing(reg), registry=reg)
    snap = engine.evaluate(now=0.0)  # empty ring: all burns None
    assert snap["status"] == "ok" and snap["burning"] == []
    for entry in snap["slos"].values():
        assert entry["burn"] is None and not entry["burning"]


def test_slo_declarations_validate_kind_fields():
    with pytest.raises(AssertionError):
        SLO(name="x", kind="latency")  # no hist_key/threshold
    with pytest.raises(AssertionError):
        SLO(name="x", kind="ratio", bad_keys=("a",))  # no total
    with pytest.raises(AssertionError):
        SLO(name="x", kind="rate", event_keys=("a",))  # no max_per_hour
    with pytest.raises(AssertionError):
        SLOEngine(
            TimeSeriesRing(MetricsRegistry()),
            slos=(_latency_slo(), _latency_slo()),  # duplicate names
        )


# --------------------------------------------------------- rolling windows


def test_rolling_windows_bounds_and_pruning():
    rw = RollingWindows(maxlen=3)
    assert rw.mean("v") is None and rw.n("v") == 0
    for x in (1.0, 2.0, 3.0, 4.0):
        rw.push("v", x)
    assert rw.n("v") == 3  # bounded: 1.0 evicted
    assert rw.values("v") == [2.0, 3.0, 4.0]
    assert rw.mean("v") == pytest.approx(3.0)
    rw.push("w", 9.0)
    assert sorted(map(str, rw.keys())) == ["v", "w"]
    rw.prune(keep=["w"])
    assert rw.keys() == ["w"] and rw.n("v") == 0


# ------------------------------------------------------------ quality plane


def test_quality_monitor_labelled_rolling_and_gauges():
    reg = MetricsRegistry()
    qm = QualityMonitor(QualityConfig(k=3, window=4), registry=reg)
    qm.observe([1, 2, 3], relevant=[1])  # hit at rank 1
    qm.observe([4, 5, 6], relevant=[1])  # miss
    s = qm.summary()
    assert s["n_labelled"] == 2 and s["k"] == 3
    assert s["recall"] == pytest.approx(0.5)
    assert 0.0 < s["ndcg"] < 1.0
    assert reg.gauge("quality_recall", k="3").value() == pytest.approx(0.5)
    assert reg.gauge("quality_ndcg", k="3").value() == pytest.approx(s["ndcg"])


def test_drift_rising_edge_rearm_and_min_batches():
    rng = np.random.default_rng(0)
    table = rng.standard_normal((32, D)).astype(np.float32)
    bus = EventBus()
    cfg = QualityConfig(drift_ewma=0.5, drift_threshold=0.5, drift_min_batches=3)
    qm = QualityMonitor(cfg, bus=bus)
    assert qm.observe_queries(table[:4]) is None  # no reference yet
    qm.set_reference(table, version=7)
    matched = lambda: table[rng.integers(0, 32, size=8)]
    shifted = lambda: matched() + 5.0
    # batches 2..3 are shifted but under min_batches: no judgement yet
    qm.observe_queries(shifted())
    assert not qm.drifting and bus.last("quality_drift") is None
    qm.observe_queries(shifted())  # batch 3 >= min_batches: rising edge
    ev = bus.last("quality_drift")
    assert ev is not None and qm.drifting
    assert ev.details["table_version"] == 7
    assert ev.details["score"] > ev.details["threshold"]
    qm.observe_queries(shifted())  # still drifted: latched, no second event
    assert bus.counts()["quality_drift"] == 1
    for _ in range(12):  # EWMA decays back onto the reference: re-arms
        qm.observe_queries(matched())
    assert not qm.drifting
    qm.observe_queries(shifted())
    qm.observe_queries(shifted())
    assert bus.counts()["quality_drift"] == 2  # second rising edge fires


def test_watch_db_follows_swaps_and_detaches():
    db = _make_db()
    bus = EventBus()
    qm = QualityMonitor(bus=bus)
    detach_q = qm.watch_db(db)
    detach_b = bus.watch_db(db)
    assert qm.summary()["ref_table_version"] == db.table_version
    v1 = db.swap_table(
        db.embeddings + 1.0, expect_current=db.table_version
    )
    assert qm.summary()["ref_table_version"] == v1  # re-froze on swap
    assert bus.last("swap").details["version"] == v1
    detach_q()
    detach_b()
    detach_q()  # idempotent (remove_swap_listener contract)
    db.swap_table(db.embeddings + 2.0, expect_current=v1)
    assert qm.summary()["ref_table_version"] == v1  # no longer following
    assert bus.last("swap").details["version"] == v1  # no new event


# ------------------------------------------------------------- HTTP surface


def _get(url):
    with urllib.request.urlopen(url, timeout=5) as resp:
        return resp.status, json.loads(resp.read())


def test_slo_and_traces_endpoints():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    bus = EventBus()
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(_latency_slo(),), bus=bus, registry=reg)
    tracer = RouteTracer(sample_every=1, seed=0)
    tid = tracer.record(
        batch_size=4, bucket=4, path="index", table_version=0,
        stage_version=0, spans=[("embed", 1.0)], total_ms=15.0,
    ).trace_id
    h.record(15.0, exemplar=tid)
    ring.tick(now=0.0)
    ring.tick(now=10.0)
    server = ObsServer(registry=reg, bus=bus, slo=engine, tracer=tracer).start()
    try:
        base = f"http://{server.host}:{server.port}"
        code, snap = _get(f"{base}/slo")  # a scrape judges live
        assert code == 200 and "lat" in snap["slos"]
        assert snap["slos"]["lat"]["p99_exemplar"] == tid
        code, trace = _get(f"{base}/traces?id={tid}")
        assert code == 200 and trace["trace_id"] == tid
        assert trace["spans"] == {"embed": 1.0}
        with pytest.raises(urllib.error.HTTPError) as exc_info:
            _get(f"{base}/traces?id=99999")
        assert exc_info.value.code == 404
        code, recs = _get(f"{base}/traces?since=-1")
        assert code == 200 and [r["trace_id"] for r in recs] == [tid]
    finally:
        server.stop()


# ----------------------------------------------------------------- repro-obs


def test_follow_events_tails_with_since_cursor():
    bus = EventBus()
    bus.publish("swap", plane="control", version=1)
    bus.publish("rollback", plane="control", condemned_version=1,
                restored_version=2, ndcg=0.5, baseline=0.9)
    server = ObsServer(bus=bus, registry=MetricsRegistry()).start()
    try:
        base = f"http://{server.host}:{server.port}"
        out = io.StringIO()
        assert follow_events(base, interval=0.0, max_polls=1, out=out) == 2
        text = out.getvalue()
        assert "swap" in text and "rollback" in text
        # second poll from a fresh cursorless call reprints; but a single
        # call's cursor advances — publish one more and poll again
        out2 = io.StringIO()
        bus.publish("cooldown", plane="control", purged=3)
        assert follow_events(base, interval=0.0, max_polls=1, out=out2) == 3
    finally:
        server.stop()


def test_watch_panel_renders_burning_slo_with_exemplar_link():
    health = {"status": "degraded"}
    slo_snap = {
        "status": "burning",
        "burning": ["route_p99_budget"],
        "slos": {
            "route_p99_budget": {
                "kind": "latency", "burning": True, "burn": 14.9,
                "threshold_ms": 10.0, "p99_ms": 23.4, "p99_exemplar": 42,
                "description": "", "windows": [],
            },
        },
    }
    trace = {"spans": {"embed": 9.0, "score": 13.1}, "batch_size": 16,
             "path": "exact", "table_version": 3}
    panel = render_watch_panel(health, slo_snap, lambda tid: trace)
    assert "health: degraded" in panel
    assert "BURNING" in panel and "p99=23.40ms vs 10ms" in panel
    assert "trace #42" in panel and "table=v3" in panel
    # unresolvable exemplar degrades to "(not retained)"
    panel2 = render_watch_panel(health, slo_snap, lambda tid: None)
    assert "(not retained)" in panel2
    # no engine wired at all
    assert "engine not wired" in render_watch_panel({"status": "ok"}, None)


def test_watch_fetches_live_panel_frames():
    reg = MetricsRegistry()
    ring = TimeSeriesRing(reg)
    engine = SLOEngine(ring, slos=(_latency_slo(),), registry=reg)
    monitor = HealthMonitor(slo=engine)
    server = ObsServer(monitor=monitor, registry=reg, slo=engine).start()
    try:
        out = io.StringIO()
        frames = watch(f"http://{server.host}:{server.port}",
                       interval=0.0, iterations=2, out=out)
        assert frames == 2
        text = out.getvalue()
        assert text.count("health: ok") == 2 and "lat" in text
    finally:
        server.stop()


def test_report_since_filters_trace_jsonl(tmp_path, capsys):
    from repro.obs.report import main as report_main

    recs = [
        {"trace_id": i, "ts": 100.0 * (i + 1), "batch_size": 4, "bucket": 4,
         "path": "index", "table_version": 0, "stage_version": 0,
         "spans": {"embed": 1.0}, "total_ms": 2.0}
        for i in range(3)
    ]
    p = tmp_path / "t.jsonl"
    p.write_text("\n".join(json.dumps(r) for r in recs) + "\n")
    assert report_main([str(p)]) == 0
    assert "3 traces" in capsys.readouterr().out
    assert report_main([str(p), "--since", "150"]) == 0
    assert "2 traces" in capsys.readouterr().out
    assert report_main([str(p), "--since", "1e9"]) == 0
    assert "no traces" in capsys.readouterr().out
