"""End-to-end system behaviour: the paper's headline claims on the synthetic
benchmarks (directional reproduction, DESIGN.md §2) and the serve launcher."""
import numpy as np
import pytest

from repro.core.evaluate import BenchmarkEvaluator


@pytest.fixture(scope="module")
def mt_results(small_bench_factory=None):
    from repro.data.benchmarks import make_metatool_like
    bench = make_metatool_like(n_tools=120, n_queries=1200)
    ev = BenchmarkEvaluator(bench)
    return {m: ev.rankings_for(m) for m in ("random", "bm25", "se", "oats-s1")}


def test_ordering_matches_paper_table4(mt_results):
    """MetaTool ordering: random < bm25 < se < oats-s1 (Table 4)."""
    n = {k: v.metrics["ndcg@5"] for k, v in mt_results.items()}
    assert n["random"] < n["bm25"] < n["se"] < n["oats-s1"]


def test_s1_gain_is_large_on_dense_outcomes(mt_results):
    """The paper's core claim: big NDCG gain at zero serving cost."""
    gain = mt_results["oats-s1"].metrics["ndcg@5"] - mt_results["se"].metrics["ndcg@5"]
    assert gain > 0.04


def test_subtask_breakdown_present(mt_results):
    r = mt_results["oats-s1"]
    assert set(r.per_subtask) == {"similar", "scenario", "reliability", "multi"}
    # 'similar' (hard negatives) is the hardest split for static embeddings
    se = mt_results["se"].per_subtask
    assert se["similar"]["ndcg@5"] <= se["scenario"]["ndcg@5"] + 0.05


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main
    stats = main([
        "--arch", "qwen2.5-3b", "--smoke", "--requests", "3",
        "--max-new-tokens", "2", "--n-tools", "40", "--n-queries", "120",
    ])
    assert stats.p50_ms < 1000  # sanity; CPU smoke


def test_train_launcher_loss_drops():
    from repro.launch.train import main
    history = main([
        "--arch", "hymba-1.5b", "--smoke", "--steps", "12",
        "--batch-size", "2", "--seq-len", "64",
    ])
    assert history[-1]["loss"] <= history[0]["loss"] + 0.05
