"""Traffic-plane tests: generator determinism, Zipf concentration,
paraphrase proximity under a bag encoder, hot-set rotation and bursts,
plus the harness's staleness gate and agreement comparison."""
import numpy as np
import pytest

from repro.traffic import (
    TrafficConfig,
    ZipfTrafficGenerator,
    agreement,
    drive,
)

D = 32


def _embed_batch(token_lists):
    out = []
    for t in token_lists:
        v = np.bincount(np.asarray(t, np.int64) % D, minlength=D)
        v = v.astype(np.float32)
        out.append(v / np.linalg.norm(v))
    return np.stack(out)


# --------------------------------------------------------------- generator


def test_same_config_emits_identical_stream():
    cfg = TrafficConfig(pool_size=32, batch_size=8, seed=9, burstiness=0.4,
                        paraphrase_p=0.5)
    a = list(ZipfTrafficGenerator(cfg).stream(12))
    b = list(ZipfTrafficGenerator(cfg).stream(12))
    assert len(a) == len(b)
    for ba, bb in zip(a, b):
        assert len(ba) == len(bb)
        for qa, qb in zip(ba, bb):
            assert np.array_equal(qa, qb)


def test_zipf_exponent_concentrates_the_hot_set():
    def distinct_fraction(s):
        cfg = TrafficConfig(zipf_s=s, pool_size=128, batch_size=32,
                            paraphrase_p=0.0, seed=1)
        gen = ZipfTrafficGenerator(cfg)
        seen = set()
        total = 0
        for batch in gen.stream(20):
            for q in batch:
                seen.add(q.tobytes())
                total += 1
        return len(seen) / total

    # hotter exponent -> fewer distinct intents behind the same volume
    assert distinct_fraction(1.6) < distinct_fraction(0.9)


def test_paraphrase_stays_near_duplicate_under_bag_encoder():
    cfg = TrafficConfig(pool_size=4, batch_size=16, query_len=24,
                        paraphrase_p=1.0, jitter_tokens=1, seed=2)
    gen = ZipfTrafficGenerator(cfg)
    originals = _embed_batch(gen._pool)
    batch = gen.next_batch()
    emb = _embed_batch(batch)
    # every jittered request stays close to SOME pool intent (length is
    # preserved: drop one of 24 tokens, append a fresh one -> cosine ~0.96)
    best = (emb @ originals.T).max(axis=1)
    assert (best > 0.9).all()
    # and the jitter is real: token rows differ from every original
    assert all(
        not any(np.array_equal(q, p) for p in gen._pool) for q in batch
    )


def test_pool_queries_are_cycled_and_validated():
    pool = [np.arange(10, dtype=np.int64), np.arange(50, 62, dtype=np.int64)]
    cfg = TrafficConfig(pool_size=5, batch_size=4, paraphrase_p=0.0, seed=3)
    gen = ZipfTrafficGenerator(cfg, pool=pool)
    assert len(gen._pool) == 5
    assert np.array_equal(gen._pool[0], gen._pool[2])  # cycled modulo 2
    with pytest.raises(AssertionError):
        ZipfTrafficGenerator(
            TrafficConfig(jitter_tokens=2, seed=3),
            pool=[np.arange(3, dtype=np.int64)],  # too short to jitter
        )


def test_hot_set_rotation_changes_the_stream():
    base = dict(zipf_s=1.4, pool_size=64, batch_size=16, paraphrase_p=0.0)
    steady = ZipfTrafficGenerator(TrafficConfig(seed=4, **base))
    rotating = ZipfTrafficGenerator(
        TrafficConfig(seed=4, hot_set_rotate_every=3, **base))
    steady_stream = list(steady.stream(9))
    rotating_stream = list(rotating.stream(9))
    # identical until the first rotation boundary...
    for qa, qb in zip(steady_stream[0], rotating_stream[0]):
        assert np.array_equal(qa, qb)
    # ...then the rank->intent remap makes the streams diverge
    diverged = any(
        not np.array_equal(qa, qb)
        for ba, bb in zip(steady_stream[3:], rotating_stream[3:])
        for qa, qb in zip(ba, bb)
    )
    assert diverged


def test_burstiness_varies_batch_sizes():
    flat = ZipfTrafficGenerator(TrafficConfig(batch_size=16, seed=5))
    sizes = {len(b) for b in flat.stream(10)}
    assert sizes == {16}
    bursty = ZipfTrafficGenerator(
        TrafficConfig(batch_size=16, burstiness=0.6, seed=5))
    burst_sizes = [len(b) for b in bursty.stream(20)]
    assert len(set(burst_sizes)) > 1
    assert min(burst_sizes) >= 1


# ----------------------------------------------------------------- harness


class _Result:
    def __init__(self, tools, tv, sv, cache_hit=False):
        self.tools = tools
        self.scores = [1.0] * len(tools)
        self.table_version = tv
        self.stage_version = sv
        self.cache_hit = cache_hit


class _FakeRouter:
    """Duck-typed router: serves canned versions, optionally stale."""

    def __init__(self, stale_at=None):
        class _Db:
            table_version = 5
        self.db = _Db()
        self.stage_version = 2
        self._stale_at = stale_at
        self._calls = 0

    def route_batch(self, batch):
        self._calls += 1
        tv = self.db.table_version
        if self._stale_at is not None and self._calls == self._stale_at:
            tv = self.db.table_version - 1  # a dead snapshot leaked out
        return [_Result([1], tv, self.stage_version, cache_hit=True)
                for _ in batch]


def _batches(n=4, size=3):
    rng = np.random.default_rng(6)
    return [[rng.integers(0, 50, size=8) for _ in range(size)]
            for _ in range(n)]


def test_drive_reports_clean_run():
    rep = drive(_FakeRouter(), _batches(), record=True)
    assert rep.batches == 4 and rep.queries == 12
    assert rep.stale_serves == 0 and rep.stale_examples == []
    assert rep.hit_rate == 1.0
    assert rep.qps > 0 and rep.p99_ms >= rep.p50_ms >= 0
    assert len(rep.results) == 4


def test_drive_staleness_gate_catches_dead_snapshot():
    rep = drive(_FakeRouter(stale_at=3), _batches())
    assert rep.stale_serves == 3  # every result of the stale batch
    ex = rep.stale_examples[0]
    assert ex["batch"] == 2 and ex["served"] == [4, 2]
    assert ex["window"] == [[5, 2], [5, 2]]


def test_drive_on_batch_hook_sees_version_moves_inside_window():
    router = _FakeRouter()

    def bump(i):
        if i == 2:
            router.db.table_version += 1  # concurrent swap before batch 2

    rep = drive(router, _batches(), on_batch=bump)
    # swap landed BEFORE the window was read -> still a clean run
    assert rep.stale_serves == 0


def test_agreement_compares_top1_per_query():
    a = [[_Result([1, 2], 1, 1), _Result([3], 1, 1)]]
    b = [[_Result([1, 9], 1, 1), _Result([4], 1, 1)]]
    assert agreement(a, a) == 1.0
    assert agreement(a, b) == pytest.approx(0.5)
    empty_a = [[_Result([], 1, 1)]]
    empty_b = [[_Result([], 1, 1)]]
    assert agreement(empty_a, empty_b) == 1.0  # empty agrees with empty
    assert agreement(empty_a, [[_Result([1], 1, 1)]]) == 0.0
